import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Hillclimb profiler: recompile one dry-run cell and print the top
byte/FLOP contributors (trip-count-weighted), plus collective breakdown.

Usage: python -m repro.launch.inspect_cell --arch hymba-1.5b --shape long_500k
"""
import argparse

import jax

from repro import configs
from repro.launch import dryrun, hlo_parse
from repro.launch.mesh import make_production_mesh
from repro.parallel import ctx as pctx


def top_contributors(text: str, n_chips: int, top: int = 25):
    comps = hlo_parse.parse_module(text)
    entry = comps["__entry__"]
    rows = []

    def walk(comp, mult):
        for ins in comp.instrs:
            if ins.opcode == "while":
                m = hlo_parse._WHILE_RE.search(ins.rest)
                if m:
                    cond = m.group(1) or m.group(4)
                    body = m.group(2) or m.group(3)
                    trips = (hlo_parse._trip_count(comps[cond]) or 1) \
                        if cond in comps else 1
                    walk(comps[body], mult * trips)
                continue
            if ins.opcode in hlo_parse.COLLECTIVE_OPS:
                rows.append((mult * ins.out_bytes, 0.0,
                             f"{ins.opcode} {ins.type_str[:50]}", comp.name))
                continue
            if ins.opcode in hlo_parse._BYTES_SKIP:
                continue
            if ins.opcode in ("dynamic-slice", "slice", "gather"):
                b = 2 * ins.out_bytes
            elif ins.opcode == "dynamic-update-slice":
                ops = hlo_parse._operand_names(ins)
                upd = comp.by_name.get(ops[1]) if len(ops) > 1 else None
                b = 2 * (upd.out_bytes if upd else ins.out_bytes)
            else:
                reads = sum(comp.by_name[o].out_bytes
                            for o in hlo_parse._operand_names(ins)
                            if o in comp.by_name
                            and comp.by_name[o].opcode != "constant")
                b = reads + ins.out_bytes
            f = hlo_parse._dot_flops(ins, comp) if ins.opcode in ("dot",) else 0
            rows.append((mult * b, mult * f,
                         f"{ins.opcode} {ins.name[:28]} {ins.type_str[:44]}",
                         comp.name[:28]))

    walk(entry, 1.0)
    rows.sort(reverse=True)
    print(f"{'bytes':>12s} {'flops':>12s}  instr")
    for b, f, desc, cn in rows[:top]:
        print(f"{b:12.3e} {f:12.3e}  {desc}  [{cn}]")
    rows.sort(key=lambda r: -r[1])
    print("\ntop flops:")
    for b, f, desc, cn in rows[:10]:
        if f > 0:
            print(f"{b:12.3e} {f:12.3e}  {desc}  [{cn}]")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()
    cfg = configs.get_config(args.arch).with_dtypes("bfloat16", "bfloat16")
    shape = configs.get_shape(args.shape)
    cfg = cfg.replace(remat=True,
                      seq_parallel=shape.kind in ("train", "prefill"))
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    with pctx.use_mesh(mesh), mesh:
        fn, a, in_sh, out_sh = dryrun.build_cell(cfg, shape, mesh)
        compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*a).compile()
    text = compiled.as_text()
    cost = hlo_parse.analyze(text, int(mesh.devices.size))
    print("totals:", {k: v for k, v in cost.as_dict().items()
                      if k in ("flops", "bytes", "collective_link_bytes")})
    print("collectives:", cost.collective_bytes, cost.collective_counts)
    top_contributors(text, int(mesh.devices.size), args.top)


if __name__ == "__main__":
    main()
