"""Trip-count-aware HLO analyzer.

``compiled.cost_analysis()`` counts a while-loop (lax.scan) body ONCE — for
scan-over-layers models that undercounts FLOPs/bytes/collectives by ~the
layer count (verified in tests). This module parses the compiled
*per-partition* HLO text instead and walks the call graph multiplying every
while body by its trip count (recovered from the loop condition constant).

Per instruction we accumulate:
* flops        — dot/convolution contractions (2·|out|·|contract|)
* hbm bytes    — operand reads + output writes of top-level instructions
                 (fusion internals are registers: counted at the call site)
* collectives  — per-kind link-bytes using ring-model factors and the
                 replica-group size parsed from the op.

All numbers are PER CHIP (the module is the per-partition program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e3m4": 1, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s*\((.*?)\)\s*->")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(\(?[^=]*?)\s*"
                       r"([a-z][a-z0-9\-]*)\((.*)$")
_OPERAND_RE = re.compile(r"%[\w.\-]+")
_CALLEE_RE = re.compile(r"(?:calls|to_apply|body|condition)=(%[\w.\-]+)")
_WHILE_RE = re.compile(r"condition=(%[\w.\-]+),?\s*body=(%[\w.\-]+)|"
                       r"body=(%[\w.\-]+),?\s*condition=(%[\w.\-]+)")
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")
_GROUPS_V1_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")
_BYTES_SKIP = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "iota", "partition-id", "replica-id"}


def shape_bytes(type_str: str) -> int:
    """Bytes of 'bf16[6,64,128]{2,1,0}' or a '(tuple, of, shapes)'."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            n *= int(d)
    return n


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str  # everything after the opening paren of operands

    @property
    def out_bytes(self) -> int:
        return shape_bytes(self.type_str)


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    by_name: Dict[str, Instr] = field(default_factory=dict)


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    comment_re = re.compile(r"/\*.*?\*/")
    for raw in text.splitlines():
        line = comment_re.sub("", raw).rstrip()
        if not line:
            continue
        if not line.startswith(" "):  # computation header or closing brace
            m = _COMP_HDR_RE.match(line)
            if m and line.endswith("{"):
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry_name = cur.name
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        ins = Instr(name=m.group(1), type_str=m.group(2), opcode=m.group(3),
                    rest=m.group(4))
        cur.instrs.append(ins)
        cur.by_name[ins.name] = ins
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _operand_names(ins: Instr) -> List[str]:
    # operands are before the closing paren of the op's argument list;
    # attribute refs (body=%x) come after — strip by splitting at '),' best-effort
    args = ins.rest.split(")", 1)[0]
    return _OPERAND_RE.findall(args)


def _trip_count(cond: Computation) -> Optional[int]:
    consts = []
    for ins in cond.instrs:
        mm = _CONST_RE.search(f"= {ins.type_str} {ins.opcode}({ins.rest}")
        if ins.opcode == "constant" and ins.type_str.strip() == "s32[]":
            m2 = re.search(r"constant\((\d+)\)", "constant(" + ins.rest)
            if m2:
                consts.append(int(m2.group(1)))
    if consts:
        return max(consts)
    return None


def _dot_flops(ins: Instr, comp: Computation) -> int:
    out_elems = shape_elems(ins.type_str)
    m = _DOT_DIMS_RE.search(ins.rest)
    ops = _operand_names(ins)
    if not m or not ops:
        return 2 * out_elems  # unknown contraction — degenerate
    lhs = comp.by_name.get(ops[0])
    if lhs is None:
        return 2 * out_elems
    dims_str = _SHAPE_RE.search(lhs.type_str)
    if not dims_str or not dims_str.group(2):
        return 2 * out_elems
    lhs_dims = [int(d) for d in dims_str.group(2).split(",")]
    contract = 1
    if m.group(1):
        for i in m.group(1).split(","):
            contract *= lhs_dims[int(i)]
    return 2 * out_elems * contract


def _group_size(ins: Instr, n_chips: int) -> int:
    m = _GROUPS_V1_RE.search(ins.rest)
    if m:
        return max(len(m.group(1).split(",")), 1)
    m = _GROUPS_V2_RE.search(ins.rest)
    if m:
        return max(int(m.group(2)), 1)
    return n_chips


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_link_bytes: float = 0.0  # ring-model per-chip
    collective_counts: Dict[str, float] = field(default_factory=dict)
    unparsed_whiles: int = 0

    def add_collective(self, kind: str, nbytes: float, count: float,
                       group: int):
        self.collective_bytes[kind] = self.collective_bytes.get(kind, 0.0) + nbytes
        self.collective_counts[kind] = self.collective_counts.get(kind, 0.0) + count
        f = (group - 1) / group if group > 1 else 0.0
        if kind == "all-reduce":
            link = 2.0 * f * nbytes
        elif kind == "all-gather":
            link = f * nbytes
        elif kind == "reduce-scatter":
            link = (group - 1) * nbytes  # output is the scattered shard
        elif kind == "all-to-all":
            link = f * nbytes
        else:  # collective-permute
            link = nbytes
        self.collective_link_bytes += link

    def as_dict(self) -> dict:
        return {"flops": self.flops, "bytes": self.bytes,
                "collective_bytes": dict(self.collective_bytes),
                "collective_counts": dict(self.collective_counts),
                "collective_link_bytes": self.collective_link_bytes,
                "unparsed_whiles": self.unparsed_whiles}


def analyze(text: str, n_chips: int) -> HloCost:
    comps = parse_module(text)
    cost = HloCost()
    entry = comps.get("__entry__")
    if entry is None:
        return cost
    seen_fusion_flops: set = set()

    def flops_of_computation(comp: Computation, mult: float):
        for ins in comp.instrs:
            if ins.opcode in ("dot", "convolution"):
                cost.flops += mult * _dot_flops(ins, comp)
            elif ins.opcode == "fusion":
                m = _CALLEE_RE.search(ins.rest)
                if m and m.group(1) in comps:
                    flops_of_computation(comps[m.group(1)], mult)
            elif ins.opcode == "while":
                _walk_while(ins, mult, flops_only=True)
            elif ins.opcode in ("call", "conditional", "sort", "reduce",
                                "map", "scatter", "reduce-window",
                                "select-and-scatter"):
                m = _CALLEE_RE.search(ins.rest)
                if m and m.group(1) in comps:
                    flops_of_computation(comps[m.group(1)], mult)

    def _fusion_read_bytes(ins: Instr, comp: Computation) -> int:
        """Reads of a fusion: parameters consumed ONLY through slice-like
        ops are charged at the slice size (real hardware streams the slice,
        not the whole stacked operand — critical for scan-over-layers where
        the per-layer weight slice is fused with its consumers)."""
        m = _CALLEE_RE.search(ins.rest)
        fused = comps.get(m.group(1)) if m else None
        operands = _operand_names(ins)
        sizes = []
        for i, op_name in enumerate(operands):
            src = comp.by_name.get(op_name)
            if src is None or src.opcode == "constant":
                sizes.append(0)
                continue
            full = src.out_bytes
            if fused is None:
                sizes.append(full)
                continue
            # find the fused parameter(i) and how it is consumed
            param_name = None
            for fi in fused.instrs:
                if fi.opcode == "parameter" and fi.rest.startswith(f"{i})"):
                    param_name = fi.name
                    break
            if param_name is None:
                sizes.append(full)
                continue
            users = [fi for fi in fused.instrs
                     if param_name in _operand_names(fi)]
            if users and all(u.opcode in ("dynamic-slice", "slice", "gather")
                             for u in users):
                sizes.append(sum(u.out_bytes for u in users))
            elif users and all(u.opcode == "dynamic-update-slice"
                               for u in users):
                # in-place region write: charge the update size
                upd = 0
                for u in users:
                    ops_u = _operand_names(u)
                    s2 = fused.by_name.get(ops_u[1]) if len(ops_u) > 1 else None
                    upd += s2.out_bytes if s2 is not None else u.out_bytes
                sizes.append(upd)
            else:
                sizes.append(full)
        return sum(sizes)

    def bytes_of_computation(comp: Computation, mult: float):
        for ins in comp.instrs:
            if ins.opcode == "while":
                _walk_while(ins, mult, flops_only=False)
                continue
            if ins.opcode in COLLECTIVE_OPS:
                g = _group_size(ins, n_chips)
                cost.add_collective(ins.opcode, mult * ins.out_bytes, mult, g)
                continue
            if ins.opcode in _BYTES_SKIP:
                continue
            if ins.opcode in ("dynamic-slice", "slice", "gather"):
                # reads only the sliced region ≈ output size
                cost.bytes += mult * 2 * ins.out_bytes
                continue
            if ins.opcode == "dynamic-update-slice":
                # reads the update, writes that region in place
                ops = _operand_names(ins)
                upd = comp.by_name.get(ops[1]) if len(ops) > 1 else None
                nb = upd.out_bytes if upd is not None else ins.out_bytes
                cost.bytes += mult * 2 * nb
                continue
            if ins.opcode == "fusion":
                reads = _fusion_read_bytes(ins, comp)
                out_b = ins.out_bytes
                # a fusion whose ROOT is a dynamic-update-slice writes only
                # the updated region; approximate with the update size
                mdus = _CALLEE_RE.search(ins.rest)
                fused = comps.get(mdus.group(1)) if mdus else None
                if fused and fused.instrs and \
                        fused.instrs[-1].opcode == "dynamic-update-slice":
                    ops_u = _operand_names(fused.instrs[-1])
                    s2 = fused.by_name.get(ops_u[1]) if len(ops_u) > 1 else None
                    if s2 is not None:
                        out_b = s2.out_bytes
                cost.bytes += mult * (reads + out_b)
                continue
            reads = 0
            for op_name in _operand_names(ins):
                src = comp.by_name.get(op_name)
                if src is not None and src.opcode not in ("constant",):
                    reads += src.out_bytes
            cost.bytes += mult * (reads + ins.out_bytes)
            if ins.opcode in ("call", "conditional"):
                m = _CALLEE_RE.search(ins.rest)
                if m and m.group(1) in comps:
                    bytes_of_computation(comps[m.group(1)], mult)

    def _walk_while(ins: Instr, mult: float, flops_only: bool):
        m = _WHILE_RE.search(ins.rest)
        if not m:
            cost.unparsed_whiles += 1
            return
        cond_name = m.group(1) or m.group(4)
        body_name = m.group(2) or m.group(3)
        trips = None
        if cond_name in comps:
            trips = _trip_count(comps[cond_name])
        if trips is None:
            trips = 1
            cost.unparsed_whiles += 1
        body = comps.get(body_name)
        if body is None:
            return
        if flops_only:
            flops_of_computation(body, mult * trips)
        else:
            bytes_of_computation(body, mult * trips)

    flops_of_computation(entry, 1.0)
    bytes_of_computation(entry, 1.0)
    return cost
