"""Fleet telemetry demo: model-referenced residuals catch drift at (or
before) the in-step CUSUM detector, with the full obs artifact trail.

A fleet of two-tier tenants runs the paper's Algorithm C shape; mid-window
every stream's record rate jumps 8x. Two independent watchers see it:

  1. the jitted engine step's ``DriftEstimator`` (PR-4's CUSUM over the
     analytic K/t entry law), which triggers the constrained re-solve;
  2. ``repro.obs``'s ``ResidualMonitor`` — a host-side replica built
     purely from the meter's cumulative write counters, testing the
     realized-minus-expected residual against the same Bernstein
     concentration budgets.

Because the monitor's excursion statistic equals the detector's CUSUM
statistic, the alert channel flags every drifted stream in the same
chunk the detector fires — before the re-planner consumes the evidence —
while costing nothing inside the jitted step. The demo prints the
per-stream race, writes the metrics.json / metrics.prom / events.jsonl
artifacts, and then re-runs the identical fleet config to assert the
jit caches are warm (100% hit: zero recompiles on the second run).

Run: PYTHONPATH=src python examples/fleet_telemetry.py [--streams 6]
"""
import argparse
import time

import numpy as np

from repro.core import constraints as cons, costs, simulator
from repro.obs import Observability, ObsConfig, jits
from repro.online import DriftConfig, ReplanConfig, evaluate
from repro.streams import StreamSpec


def make_fleet(m: int, docs: int, k: int):
    """Interior-crossover two-tier tenants (write-cheap/read-expensive
    hot tier) so the planner puts every boundary mid-stream."""
    specs = []
    for i in range(m):
        wl = costs.WorkloadSpec(n_docs=docs, k=k, doc_gb=1e-4,
                                window_months=0.5)
        hot = costs.TierCosts("hot", put_per_doc=1e-6, get_per_doc=2.7e-4,
                              storage_per_gb_month=0.05)
        cold = costs.TierCosts("cold", put_per_doc=8e-5, get_per_doc=1e-6,
                               storage_per_gb_month=0.02)
        specs.append(StreamSpec(
            stream_id=i, k=k,
            cost_model=costs.TwoTierCostModel(tier_a=hot, tier_b=cold,
                                              workload=wl)))
    return specs


def run_once(traces, specs, args, obs):
    return evaluate.run_fleet(
        traces, specs,
        replan=ReplanConfig(drift=DriftConfig(alpha=args.alpha)),
        chunk=args.chunk,
        constraints=cons.ConstraintSet(cons.TierCapacity(0, 4 * args.k)),
        obs=obs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=6)
    ap.add_argument("--docs", type=int, default=12000)
    ap.add_argument("--k", type=int, default=64)
    ap.add_argument("--drift-at", type=int, default=3000)
    ap.add_argument("--multiplier", type=float, default=8.0)
    ap.add_argument("--chunk", type=int, default=64)
    ap.add_argument("--alpha", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=5)
    ap.add_argument("--out", default="obs_out",
                    help="directory for the obs artifacts")
    args = ap.parse_args()
    rng = np.random.default_rng(args.seed)

    specs = make_fleet(args.streams, args.docs, args.k)
    traces = np.stack([
        simulator.drifted_rank_trace(args.docs, rng,
                                     [(args.drift_at, args.multiplier)])
        for _ in range(args.streams)])

    obs = Observability(ObsConfig(residual_alpha=args.alpha, costs=True))
    t0 = time.time()
    engine = run_once(traces, specs, args, obs)
    print(f"fleet of {args.streams} x {args.docs} docs "
          f"({args.multiplier:g}x drift at {args.drift_at}) in "
          f"{time.time() - t0:.1f}s")

    # --- the race: residual alert channel vs in-step CUSUM detector ------
    alerts = engine.residual_alerts()
    detected = {}
    for ev in engine.replan_events:
        detected.setdefault(ev.stream_id, ev.position)
    failures = []
    won = 0
    print("stream  residual-alert  cusum-detect  alert<=detect")
    for sid in range(args.streams):
        a, d = alerts.get(sid), detected.get(sid)
        ok = a is not None and d is not None and a <= d
        won += ok
        print(f"{sid:>6}  {str(a):>14}  {str(d):>12}  {str(ok):>13}")
    frac = won / max(len(detected), 1)
    print(f"residual channel at-or-before CUSUM on {won}/{len(detected)} "
          f"detected streams ({frac:.0%})")
    if frac < 0.9:
        failures.append("residual alerts trailed the CUSUM detector")

    snap = engine.obs_snapshot()
    wz = snap["residuals"]["writes"]
    print(f"write-law residual: fleet realized={wz['fleet_realized']:.0f} "
          f"expected={wz['fleet_expected']:.1f} max|z|={wz['max_abs_z']:.2f}")
    em = snap["engine"]
    print(f"device counters: docs={em['docs']} admits={em['admits']} "
          f"evictions={em['evictions']} "
          f"filter_pass_rate={em['filter_pass_rate']:.3f} "
          f"chunks={em['chunks']}")

    # --- per-tenant cost attribution: realized vs planned regret ---------
    print()
    print(evaluate.format_regret_table(evaluate.regret_table(engine)))
    cm = engine.cost_summary()
    if not np.all(np.isfinite(cm["regret"])):
        failures.append("non-finite regret in the cost summary")

    paths = obs.write(args.out)
    print("obs artifacts: " + ", ".join(sorted(paths.values())))

    # --- jit-cache introspection: identical config must be all hits ------
    before = {name: p["misses"] for name, p in jits.snapshot().items()}
    run_once(traces, specs, args, Observability(ObsConfig(
        residual_alpha=args.alpha, costs=True)))
    after = jits.snapshot()
    for name, p in sorted(after.items()):
        new_misses = p["misses"] - before.get(name, 0)
        print(f"jit probe {name}: calls={p['calls']} misses={p['misses']} "
              f"compile_s={p['compile_s']:.2f} "
              f"(re-run recompiles: {new_misses})")
        if new_misses:
            failures.append(
                f"jit probe {name} recompiled on an identical re-run")
    if not after:
        failures.append("no jit probes registered")

    if failures:
        raise SystemExit("; ".join(failures))
    print("fleet telemetry demo OK")


if __name__ == "__main__":
    main()
