"""Chaos drill: kill -9 mid-window, restore, resume — bitwise — then
survive a tier outage and print the regret table.

Phase 1 (crash recovery): a child process ingests the fleet with
chunk-boundary checkpointing on and SIGKILLs *itself* at a seeded chunk
that is not a checkpoint boundary (the worst case: the cursor is past
the last committed save, an async write may be mid-flight). The parent
then restores the latest committed checkpoint onto a freshly built
engine, replays the remaining chunks, and asserts the final reservoirs
and every host ledger are bitwise identical to an uninterrupted
reference run (sha256 digests printed for both).

Phase 2 (tier outage): the recovered engine keeps serving; mid-window
the DRAM tier is declared failed — affected tenants are evacuated
through the constrained suffix re-solve (the failed tier masked from
the feasible set), ingest continues with the tier empty, and recovery
re-admits it after hysteresis. The evacuation bill is credited to the
planned trajectory, so the closing per-tenant regret table
(``online.evaluate.regret_table``) charges the outage to the operator,
not the tenants — and no budget-burn alert false-fires.

Artifacts: the checkpoint directory and the streamed obs event log
(checkpoint / tier_outage / tier_evacuation / tier_recovered events)
are left on disk for CI upload.

Run: PYTHONPATH=src python examples/chaos_recovery.py [--out chaos_out]
"""
import argparse
import hashlib
import os
import signal
import subprocess
import sys

import numpy as np

from repro.core import topology
from repro.obs import Observability, ObsConfig
from repro.online import DriftConfig, ReplanConfig, evaluate
from repro.resilience import FleetCheckpointer, TierOutage
from repro.streams.engine import StreamEngine, StreamSpec

W = 32  # docs per tenant per chunk


def build_engine(tenants, total_docs, k, events_path=None):
    """The drill fleet: 3-tier (HBM -> DRAM -> disk) tenants — half
    planner-placed from their cost models, half pinned to explicit
    boundaries whose DRAM band spans the window (so the outage has
    residents AND future arrivals to move) — with drift-driven
    re-planning and cost attribution on: the full state surface a
    checkpoint must carry."""
    specs = []
    for t in range(tenants):
        cm = topology.hbm_dram_disk_preset(
            n_docs=total_docs, k=k, doc_gb=1e-4,
            window_seconds=30.0 * (1 + t % 3))
        if t % 2:  # pinned, but still priced by the model
            specs.append(StreamSpec(stream_id=t, k=k, cost_model=cm,
                                    boundaries=(32.0, total_docs * 0.8)))
        else:
            specs.append(StreamSpec(stream_id=t, k=k, cost_model=cm))
    obs = Observability(ObsConfig(costs=True, events_path=events_path))
    return StreamEngine(specs, obs=obs,
                        replan=ReplanConfig(drift=DriftConfig(alpha=0.05)))


def make_chunk(engine, i, seed):
    """Chunk ``i`` as a pure function of its index (the crash replays
    chunks from their index; determinism is the whole game)."""
    r = np.random.default_rng(seed + i)
    dense = []
    for b in engine.buckets:
        s = r.random((b.m, W)).astype(np.float32)
        if i >= 4:  # mid-window heat-up so the drift/replan path runs
            s[: b.m // 2] += 0.5
        ids = np.tile(np.arange(i * W, (i + 1) * W, dtype=np.int32),
                      (b.m, 1))
        dense.append((s, ids))
    return dense


def digest(engine) -> str:
    """sha256 over the survivors and every host ledger — the bitwise
    acceptance check collapsed to one line."""
    h = hashlib.sha256()
    for sid in sorted(engine.finalize()):
        h.update(np.ascontiguousarray(engine.finalize()[sid]))
    for name, arr in sorted(engine.meter.state_dict().items()):
        h.update(np.ascontiguousarray(arr))
    if engine._cost_monitor is not None:
        for name, arr in sorted(engine._cost_monitor.state_dict().items()):
            h.update(np.ascontiguousarray(np.asarray(arr)))
    return h.hexdigest()


def child(args):
    """Ingest with checkpointing on; SIGKILL ourselves mid-window."""
    eng = build_engine(args.tenants, args.total_docs, args.k,
                      events_path=os.path.join(args.out,
                                               "child_events.jsonl"))
    ck = FleetCheckpointer(args.ckpt_dir, every=args.ckpt_every)
    eng.attach_checkpointer(ck)
    for i in range(args.chunks):
        eng.ingest_dense(make_chunk(eng, i, args.seed))
        if i == args.kill_at:
            # kill -9: no atexit, no flush, an async npy write possibly
            # mid-flight — exactly what the atomic rename must survive
            os.kill(os.getpid(), signal.SIGKILL)
    raise SystemExit("child was supposed to die")  # pragma: no cover


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", type=int, default=6)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--chunks", type=int, default=12)
    ap.add_argument("--extra-chunks", type=int, default=6,
                    help="chunks served through the tier-outage phase")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kill-at", type=int, default=7,
                    help="seeded chunk index at which the child SIGKILLs "
                         "itself (chosen off the checkpoint cadence so "
                         "restore must replay)")
    ap.add_argument("--ckpt-every", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="chaos_ckpt")
    ap.add_argument("--out", default="chaos_out")
    ap.add_argument("--role", default="parent", choices=["parent", "child"])
    args = ap.parse_args()
    args.total_docs = (args.chunks + args.extra_chunks) * W
    os.makedirs(args.out, exist_ok=True)
    if args.role == "child":
        child(args)
        return

    # ---- reference: the uninterrupted run ------------------------------
    ref = build_engine(args.tenants, args.total_docs, args.k)
    for i in range(args.chunks):
        ref.ingest_dense(make_chunk(ref, i, args.seed))
    ref_digest = digest(ref)
    print(f"reference: {args.chunks} chunks, digest {ref_digest[:16]}…")

    # ---- phase 1: kill -9 mid-window, restore, replay ------------------
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--role", "child",
         "--tenants", str(args.tenants), "--k", str(args.k),
         "--chunks", str(args.chunks),
         "--extra-chunks", str(args.extra_chunks),
         "--seed", str(args.seed), "--kill-at", str(args.kill_at),
         "--ckpt-every", str(args.ckpt_every),
         "--ckpt-dir", args.ckpt_dir, "--out", args.out],
        env={**os.environ,
             "PYTHONPATH": os.pathsep.join(
                 [p for p in (os.environ.get("PYTHONPATH"),) if p]
                 + [os.path.join(os.path.dirname(__file__), "..", "src")])})
    assert proc.returncode in (-signal.SIGKILL, 128 + signal.SIGKILL), (
        f"child should have died by SIGKILL, got {proc.returncode}")
    print(f"child killed -9 at chunk {args.kill_at} "
          f"(rc={proc.returncode})")

    eng = build_engine(args.tenants, args.total_docs, args.k,
                       events_path=os.path.join(args.out, "events.jsonl"))
    ck = FleetCheckpointer(args.ckpt_dir, every=args.ckpt_every)
    gen = ck.restore(eng)
    cursor = eng.chunks_ingested
    assert cursor <= args.kill_at, "checkpoint is ahead of the kill?"
    print(f"restored generation {gen} at chunk {cursor}; "
          f"replaying {args.chunks - cursor} chunks")
    eng.attach_checkpointer(ck)
    for i in range(cursor, args.chunks):
        eng.ingest_dense(make_chunk(eng, i, args.seed))
    rec_digest = digest(eng)
    print(f"recovered:  {args.chunks} chunks, digest {rec_digest[:16]}…")
    assert rec_digest == ref_digest, (
        f"recovery is NOT bitwise: {ref_digest} != {rec_digest}")
    print("phase 1 OK: crash/restore/resume is bitwise invisible")

    # ---- phase 2: tier outage under load -------------------------------
    tier = 1  # DRAM
    mid = args.chunks + args.extra_chunks // 2
    occupied = int(eng.meter.occupancy[:, tier].sum())
    with TierOutage(eng, tier=tier, burn_grace=8, hysteresis=2) as out:
        print(f"tier {tier} outage: {out.summary['rows_evacuated']} "
              f"tenants evacuated ({occupied} resident docs), "
              f"bill {out.summary['bill']:.3e}, "
              f"{len(out.summary['infeasible_rows'])} infeasible")
        for i in range(args.chunks, mid):
            eng.ingest_dense(make_chunk(eng, i, args.seed))
        assert int(eng.meter.occupancy[:, tier].sum()) == 0, (
            "failed tier still holds documents")
    for i in range(mid, args.chunks + args.extra_chunks):
        eng.ingest_dense(make_chunk(eng, i, args.seed))
    mon = eng._cost_monitor
    evac = np.zeros(eng.m, bool)
    evac[out.summary["rows"]] = True
    assert not mon.burn_alerted[evac].any(), (
        "budget-burn alert false-fired on the evacuation bill")
    print(f"phase 2 OK: tier {tier} evacuated, served through the "
          f"outage, recovered after hysteresis")

    eng.finalize()
    rows = evaluate.regret_table(eng)
    print(evaluate.format_regret_table(rows))
    eng._obs.write(args.out)
    res = eng.obs_snapshot()["resilience"]
    print(f"resilience: {res['tier_outages']} outage(s), checkpoint "
          f"generation {res['checkpoint']['generation']}, artifacts in "
          f"{args.out}/ + {args.ckpt_dir}/")
    print("CHAOS-OK")


if __name__ == "__main__":
    main()
