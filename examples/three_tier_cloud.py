"""Three-tier worked example: AWS storage hierarchies under a top-K
stream workload.

The paper's two-tier Algorithm C generalizes to any ordered hierarchy
because the write law E[writes at i] = min(1, K/(i+1)) is non-increasing:
the optimal placement is a non-decreasing boundary vector with one eq.
17/21-style crossover per adjacent tier pair (``repro.core.topology``).
This example

1. plans the flagship 3-tier hierarchy — EFS → S3 Standard → Glacier-IR,
   the paper's case study 2 extended one tier down — in closed form and
   prints the strategy table next to the brute-force grid optimum (a
   genuine 3-boundary migration cascade),
2. shows the S3 Standard → Standard-IA → Glacier-IR lifecycle hierarchy,
   where the validity gate *collapses* the IA tier: its per-request touch
   cost always outweighs its rental advantage, so the optimal cascade
   skips straight from Standard to Glacier,
3. replays a scaled-down trace through ``core.simulator`` with the chosen
   boundary vector and reconciles the per-tier ledger against the analytic
   segment expectations (the §VIII validation, now per tier).

Run: PYTHONPATH=src python examples/three_tier_cloud.py
"""
import argparse

import numpy as np

from repro.core import costs, placement, shp, simulator, topology


def plan_table(model):
    """Print each strategy family's expected cost, paper-table style."""
    rows = []
    for t in range(model.t):
        sc = shp.cost_ntier_no_migration(model, shp.single_tier_bounds(model, t))
        rows.append((f"all[{model.tier_names[t]}]", sc))
    plan = shp.plan_placement_ntier(model)
    best = plan.best
    rows.append((f"chosen[{plan.strategy}]", best))
    print(f"{'strategy':<34}{'total':>10}  boundaries (b/N)")
    for name, sc in rows:
        bs = ", ".join(f"{b:.4f}" for b in sc.bounds_over_n)
        print(f"{name:<34}{sc.total:>10.2f}  [{bs}]")
    return plan


def reconcile_sim(model, plan, n_sim, trials, seed):
    """Trace-driven validation at reduced scale: same boundary *fractions*,
    per-tier write counts vs the analytic segment expectation."""
    wl = model.workload
    scale = n_sim / wl.n_docs
    k_sim = max(int(wl.k * scale), 8)
    sim_model = model.replace(workload=costs.WorkloadSpec(
        n_docs=n_sim, k=k_sim, doc_gb=wl.doc_gb,
        window_months=wl.window_months))
    bounds = tuple(b * scale for b in plan.boundaries)
    pol = placement.Policy(boundaries=bounds, migrate_at_r=plan.migrate,
                           name=plan.strategy)
    rng = np.random.default_rng(seed)
    writes = np.zeros(model.t)
    totals = []
    for _ in range(trials):
        trace = simulator.random_rank_trace(n_sim, rng)
        res = simulator.simulate(trace, k_sim, pol, sim_model)
        writes += res.writes_per_tier
        # eq. 20 convention: the migration strategy's expected total
        # excludes the final read the simulator meters
        totals.append(res.cost_total - (res.cost_reads if plan.migrate else 0))
    writes /= trials
    edges = np.concatenate([[0.0], bounds, [n_sim]])
    exact = np.diff(np.where(edges > 0,
                             shp.expected_cum_writes(edges - 1.0, k_sim), 0.0))
    print(f"\ntrace-driven validation (N={n_sim}, K={k_sim}, "
          f"{trials} trials):")
    print(f"{'tier':<16}{'sim writes':>12}{'analytic':>12}{'rel err':>10}")
    for t, name in enumerate(model.tier_names):
        err = (writes[t] - exact[t]) / max(exact[t], 1e-12)
        print(f"{name:<16}{writes[t]:>12.1f}{exact[t]:>12.1f}{err:>+10.2%}")
    fn = shp.cost_ntier_migration if plan.migrate else shp.cost_ntier_no_migration
    expected = fn(sim_model, bounds, exact=True).total
    sim_mean = float(np.mean(totals))
    print(f"cost: simulated ${sim_mean:.4f} vs analytic ${expected:.4f} "
          f"({(sim_mean - expected) / expected:+.2%})")
    return writes, exact


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=int(1e8))
    ap.add_argument("--k", type=int, default=int(1e5))
    ap.add_argument("--doc-mb", type=float, default=1.0)
    ap.add_argument("--window-months", type=float, default=3.0)
    ap.add_argument("--sim-docs", type=int, default=30_000)
    ap.add_argument("--trials", type=int, default=4)
    args = ap.parse_args()

    topo = topology.aws_efs_s3_glacier()
    wl = costs.WorkloadSpec(n_docs=args.n_docs, k=args.k,
                            doc_gb=args.doc_mb * costs.GB_PER_MB,
                            window_months=args.window_months)
    model = topo.cost_model(wl)
    print(f"topology: {' -> '.join(topo.tier_names)}")
    print(f"workload: N={wl.n_docs:.0e} K={wl.k:.0e} doc={args.doc_mb}MB "
          f"window={wl.window_months}mo\n")
    plan = plan_table(model)
    bt, bb, bm = shp.brute_force_plan_ntier(model, grid=64)
    print(f"\nbrute-force grid optimum: ${bt:.2f} at "
          f"[{', '.join(f'{b / wl.n_docs:.4f}' for b in bb)}] "
          f"migrate={bm} (closed form ${plan.total:.2f})")

    ia_topo = topology.aws_s3_tiering()
    ia_plan = shp.plan_placement_ntier(ia_topo.cost_model(wl))
    widths = np.diff([0.0, *ia_plan.boundaries, wl.n_docs]) / wl.n_docs
    print(f"\n{' -> '.join(ia_topo.tier_names)}: {ia_plan.strategy} "
          f"${ia_plan.total:.2f}, tier occupancy "
          f"[{', '.join(f'{w:.4f}' for w in widths)}]")
    print("  (the validity gate collapses Standard-IA: its PUT + retrieval "
          "touch cost\n   outweighs its rental edge, so the cascade skips "
          "straight to Glacier)")
    reconcile_sim(model, plan, args.sim_docs, args.trials, seed=0)


if __name__ == "__main__":
    main()
