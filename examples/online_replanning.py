"""Online re-planning demo: detect mid-window drift and re-solve the
constrained plan in closed form, beating the static a-priori placement.

A fleet of tenants runs the paper's two-tier Algorithm C shape (hot tier
write-cheap / read-expensive, interior r*). Mid-window, every stream's
record rate jumps by a piecewise multiplier (the weighted-record trace —
``simulator.drifted_rank_trace`` — whose entry law the detector and the
oracle both know analytically). The closed loop:

  1. ``DriftEstimator`` (inside the jitted engine step) flags the burst
     against the analytic K/t entry law,
  2. ``Replanner`` re-solves the constrained boundary objective over the
     remaining suffix (drift-conditioned write/read laws + relocation
     bill) and applies the delta,
  3. realized costs are replayed through ``core.simulator``: the
     re-planned fleet must beat the static plan and land within ~10% of
     a hindsight oracle that knows the drift onset, with zero
     reconciliation-time constraint violations.

Also demos ``AdmissionController``: an SLO-squeezed tenant that the
constrained planner would reject is admitted at a negotiated K.

Run: PYTHONPATH=src python examples/online_replanning.py [--streams 8]
"""
import argparse
import time

import numpy as np

from repro.core import constraints as cons, costs, simulator, topology
from repro.online import (AdmissionController, DriftConfig, ReplanConfig,
                          evaluate)
from repro.streams import StreamSpec


def make_fleet(m: int, docs: int, k: int, rng: np.random.Generator):
    """Heterogeneous tenants around the interior-crossover shape: hot
    tier write-cheap / read-expensive, cold tier the reverse, costs
    jittered so every tenant gets its own r*."""
    specs = []
    for i in range(m):
        wl = costs.WorkloadSpec(n_docs=docs, k=k, doc_gb=1e-4,
                                window_months=0.5)
        hot = costs.TierCosts(
            "hot", put_per_doc=1e-6,
            get_per_doc=2.7e-4 * float(rng.uniform(0.9, 1.1)),
            storage_per_gb_month=0.05)
        cold = costs.TierCosts(
            "cold", put_per_doc=8e-5 * float(rng.uniform(0.9, 1.1)),
            get_per_doc=1e-6, storage_per_gb_month=0.02)
        cm = costs.TwoTierCostModel(tier_a=hot, tier_b=cold, workload=wl)
        specs.append(StreamSpec(stream_id=i, k=k, cost_model=cm))
    return specs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=8)
    ap.add_argument("--docs", type=int, default=12000)
    ap.add_argument("--k", type=int, default=64)
    ap.add_argument("--drift-at", type=int, default=3000)
    ap.add_argument("--multiplier", type=float, default=8.0)
    ap.add_argument("--chunk", type=int, default=64)
    ap.add_argument("--alpha", type=float, default=0.05)
    ap.add_argument("--oracle-grid", type=int, default=10,
                    help="0 disables the hindsight-oracle sweep")
    ap.add_argument("--seed", type=int, default=1)
    args = ap.parse_args()
    rng = np.random.default_rng(args.seed)

    specs = make_fleet(args.streams, args.docs, args.k, rng)
    traces = np.stack([
        simulator.drifted_rank_trace(args.docs, rng,
                                     [(args.drift_at, args.multiplier)])
        for _ in range(args.streams)])
    cset = cons.ConstraintSet(cons.TierCapacity(0, 4 * args.k))

    t0 = time.time()
    ev = evaluate.evaluate_fleet(
        traces, specs,
        replan=ReplanConfig(drift=DriftConfig(alpha=args.alpha)),
        drift_at=args.drift_at if args.oracle_grid else None,
        chunk=args.chunk, constraints=cset,
        oracle_grid=max(args.oracle_grid, 1),
        drift_schedule=[(args.drift_at, args.multiplier)])
    engine = ev.engine
    applied = [e for e in engine.replan_events if e.applied]
    print(f"closed loop over {args.streams} streams x {args.docs} docs "
          f"({args.multiplier:g}x drift at {args.drift_at}) in "
          f"{time.time() - t0:.1f}s")
    print(f"replan events: {len(engine.replan_events)} "
          f"({len(applied)} applied, "
          f"{int(engine.meter.relocations.sum())} residents relocated)")
    for e in applied[: args.streams]:
        print(f"  tenant {e.stream_id} @ doc {e.position}: rho={e.rho:.2f} "
              f"r {e.old_bounds[0]:.0f} -> {e.new_bounds[0]:.0f} "
              f"(E[suffix] {e.suffix_cost_old:.4f} -> "
              f"{e.suffix_cost_new:.4f}, bill {e.move_bill:.5f})")

    print(f"fleet realized cost: static={ev.fleet_static:.4f} "
          f"replanned={ev.fleet_replanned:.4f} "
          f"({ev.fleet_replanned / ev.fleet_static:.1%} of static)")
    failures = []
    if ev.fleet_replanned >= ev.fleet_static:
        failures.append("re-planned fleet did not beat the static plan")
    if args.oracle_grid:
        print(f"drift-aware oracle plan: {ev.fleet_oracle:.4f} "
              f"(replanned is {ev.fleet_replanned / ev.fleet_oracle:.1%})")
        if ev.fleet_replanned > 1.10 * ev.fleet_oracle:
            failures.append("re-planned fleet missed the 10% oracle band")
    report = engine.check_constraints()
    print(f"constraint reconciliation ok: {report['ok']}")
    if not report["ok"]:
        failures.append("constraint violations at reconciliation")

    # --- admission control: negotiate instead of rejecting ---------------
    topo = topology.aws_archive_tiering()
    topo = topo.replace(tiers=(
        topo.tiers[0].__class__(topo.tiers[0].costs, capacity_docs=128,
                                read_latency_s=0.02),
        topo.tiers[1]))
    wl = costs.WorkloadSpec(n_docs=200_000, k=512, doc_gb=1e-3,
                            window_months=1.0)
    squeezed = topo.cost_model(wl)
    slo_set = cons.ConstraintSet(cons.ReadLatencySLO(60.0))
    dec = AdmissionController(slo_set).admit(squeezed)
    print(f"admission: K={wl.k} under a 60s SLO with a 128-doc hot tier "
          f"-> {dec.reason} (admitted={dec.admitted}, K={dec.k}, "
          f"window={dec.n_docs})")
    if not (dec.admitted and dec.negotiated and dec.k < wl.k):
        failures.append("admission controller failed to negotiate")

    if failures:
        raise SystemExit("; ".join(failures))
    print("online re-planning demo OK")


if __name__ == "__main__":
    main()
