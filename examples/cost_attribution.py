"""Cost attribution demo: a budget burn-rate alert fires a re-plan that
bends the realized-cost curve back toward the planned trajectory.

Fleet of two-tier tenants whose cold tier charges expensive writes (the
flash write-amplification regime) while the planner's a-priori boundary
keeps only the early stream prefix hot. Half the tenants drift: their
score distribution heats up mid-window (rate multiplier), so admissions
keep landing in the expensive cold tier at several times the planned
rate. The drift detector is configured nearly blind (tiny alpha) — it
is the *cost* channel (``ObsConfig(costs=True, cost_trigger=True)``)
that notices: realized spend runs past the closed-form expected-cost
trajectory, the multi-window budget burn-rate rule fires a
``budget_burn`` event, and the alert unions into the re-plan trigger.
The suffix re-solve widens the hot tier, future admits become cheap,
and the realized-cost slope drops — which this script asserts, along
with the per-tenant regret table (``online.evaluate.regret_table``).

Run: PYTHONPATH=src python examples/cost_attribution.py [--out DIR]
"""
import argparse
import sys

import numpy as np

from repro.core import constraints as cons, costs, simulator
from repro.obs import Observability, ObsConfig
from repro.online import DriftConfig, ReplanConfig, evaluate
from repro.streams.engine import StreamEngine, StreamSpec


def make_model(n: int, k: int) -> costs.TwoTierCostModel:
    """Cheap-to-write hot tier, expensive-to-write cold tier: the regime
    where admitting past the boundary is what burns the budget."""
    wl = costs.WorkloadSpec(n_docs=n, k=k, doc_gb=1e-4, window_months=0.5)
    hot = costs.TierCosts("hot", put_per_doc=1e-6, get_per_doc=2.7e-4,
                          storage_per_gb_month=0.05)
    cold = costs.TierCosts("cold", put_per_doc=8e-5, get_per_doc=1e-6,
                           storage_per_gb_month=0.02)
    return costs.TwoTierCostModel(tier_a=hot, tier_b=cold, workload=wl)


def make_fleet(m, n, k, drift_at, mult, seed):
    rng = np.random.default_rng(seed)
    cm = make_model(n, k)
    drifted = [i < m // 2 for i in range(m)]
    traces = np.stack([
        simulator.drifted_rank_trace(n, rng, [(drift_at, mult)])
        if drifted[i] else simulator.random_rank_trace(n, rng)
        for i in range(m)])
    specs = [StreamSpec(stream_id=i, k=k, cost_model=cm) for i in range(m)]
    cset = cons.ConstraintSet(cons.TierCapacity(0, 4 * k))
    return traces, specs, cset, np.asarray(drifted)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=4)
    ap.add_argument("--docs", type=int, default=12000)
    ap.add_argument("--k", type=int, default=64)
    ap.add_argument("--drift-at", type=int, default=3000)
    ap.add_argument("--multiplier", type=float, default=8.0)
    ap.add_argument("--chunk", type=int, default=64)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--oracle-grid", type=int, default=6,
                    help="hindsight-oracle sweep size for the regret "
                         "table (0 = skip the oracle column)")
    ap.add_argument("--out", default=None, metavar="DIR",
                    help="write obs artifacts (metrics/events) to DIR")
    args = ap.parse_args()

    m, n, k = args.streams, args.docs, args.k
    traces, specs, cset, drifted = make_fleet(
        m, n, k, args.drift_at, args.multiplier, args.seed)
    obs = Observability(ObsConfig(
        costs=True, cost_trigger=True, cost_alpha=0.01,
        budget_factor=1.2))
    # the detector is nearly blind (tiny alpha → huge thresholds): any
    # re-plan in this run is driven by the cost/burn channel
    eng = StreamEngine(specs, obs=obs, constraints=cset,
                       replan=ReplanConfig(drift=DriftConfig(alpha=1e-9)))

    sids = np.arange(m)
    realized_curve, planned_curve = [], []
    for t0 in range(0, n, args.chunk):
        c = min(args.chunk, n - t0)
        eng.ingest(np.repeat(sids, c),
                   traces[:, t0:t0 + c].reshape(-1),
                   np.tile(t0 + np.arange(c), m))
        mon = eng._cost_monitor
        realized_curve.append(mon.realized_total[drifted].sum())
        planned_curve.append(mon.planned_total[drifted].sum())
    eng.finalize()
    realized_curve = np.asarray(realized_curve)
    planned_curve = np.asarray(planned_curve)

    failures = []

    # --- the alert → re-plan chain -------------------------------------
    burns = [e for e in obs.tracer.events if e["name"] == "budget_burn"]
    alerts = [e for e in obs.tracer.events if e["name"] == "cost_alert"]
    print(f"cost alerts: {len(alerts)}, budget burns: {len(burns)}")
    for e in burns[:4]:
        a = e["attrs"]
        print(f"  burn: stream {a['stream_id']} at position "
              f"{a['position']} (realized/planned over the long window "
              f"= {a['burn_ratio']:.2f})")
    if not any(drifted[e["attrs"]["row"]] for e in burns + alerts):
        failures.append("no cost/burn alert fired on a drifted stream")

    cost_replans = [
        e["attrs"] for e in obs.tracer.events
        if e["name"] == "replan_decision"
        and e["attrs"]["cost_triggered"] and e["attrs"]["applied"]]
    if not cost_replans:
        failures.append("no applied re-plan was cost-triggered")
        first_replan_pos = None
    else:
        first = min(cost_replans, key=lambda a: a["position"])
        first_replan_pos = int(first["position"])
        print(f"cost-triggered re-plan: stream {first['stream_id']} at "
              f"position {first_replan_pos} "
              f"(moved {first['moved_docs']} residents)")

    # --- the curve bends ------------------------------------------------
    if first_replan_pos is not None:
        dc = args.drift_at // args.chunk
        rc = min(first_replan_pos // args.chunk, len(realized_curve) - 3)
        pre = (realized_curve[rc] - realized_curve[dc]) / max(rc - dc, 1)
        post = (realized_curve[-1] - realized_curve[rc + 1]) \
            / max(len(realized_curve) - rc - 2, 1)
        plan_slope = (planned_curve[-1] - planned_curve[rc + 1]) \
            / max(len(planned_curve) - rc - 2, 1)
        print(f"realized-cost slope (drifted tenants, per {args.chunk}-doc "
              f"chunk): pre-replan {pre:.3e} → post-replan {post:.3e} "
              f"(planned {plan_slope:.3e})")
        if not post < pre:
            failures.append(
                f"re-plan did not bend the cost curve: post {post:.3e} "
                f">= pre {pre:.3e}")

    # --- the regret table -----------------------------------------------
    table = evaluate.regret_table(
        eng, traces,
        drift_at=args.drift_at if args.oracle_grid else None,
        grid=args.oracle_grid)
    print()
    print(evaluate.format_regret_table(table))
    worst_drifted = max(table[i]["regret"] for i in range(m) if drifted[i])
    worst_calm = max(table[i]["regret"] for i in range(m) if not drifted[i])
    if not worst_drifted > worst_calm:
        failures.append("drifted tenants should out-regret calm ones "
                        f"({worst_drifted:.3e} vs {worst_calm:.3e})")

    snap = eng.obs_snapshot()["costs"]
    print(f"\nfleet: realized={snap['realized']['total']:.3e} "
          f"planned={snap['planned_total']:.3e} "
          f"regret={snap['regret']['fleet']:+.3e} "
          f"(alerts: cost={snap['alerts']['cost_alerted']} "
          f"burn={snap['alerts']['burn_alerted']})")

    if args.out:
        paths = obs.write(args.out)
        print("obs artifacts: " + ", ".join(sorted(paths.values())))

    if failures:
        print("\nFAILURES:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        raise SystemExit(1)
    print("\nOK: budget burn alert → cost-triggered re-plan → flattened "
          "realized-cost curve")


if __name__ == "__main__":
    main()
