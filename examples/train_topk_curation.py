"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the paper's top-K tiered curation as a first-class training feature.

The SHP placement is decided BEFORE the run (proactive, closed-form) from an
HBM↔host cost model; during the run the jitted train step scores every
example (fused entropy/NLL kernel path) and maintains the device reservoir,
while the host curator places retained payloads across the hot (device) /
cold (host) tiers, migrating at i = r if the plan says so. Checkpointing is
async + tiered; the loop auto-resumes after interruption.

Run (full):    PYTHONPATH=src python examples/train_topk_curation.py
Run (smoke):   PYTHONPATH=src python examples/train_topk_curation.py \
                   --steps 20 --d-model 128 --layers 2 --seq 64 --batch 4
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.configs.base import LayerSpec, ModelConfig, ShapeConfig
from repro.core import costs, placement, shp, tiers
from repro.data.curation import TopKCurator
from repro.data.pipeline import StreamLoader
from repro.models import param_count
from repro.runtime import train_loop


def build_cfg(args) -> ModelConfig:
    return ModelConfig(
        name="lm-100m", family="dense", d_model=args.d_model,
        vocab_size=args.vocab,
        layers=(LayerSpec(count=args.layers, mixer="attn", ffn="dense"),),
        n_heads=args.d_model // 64, n_kv_heads=max(args.d_model // 256, 1),
        head_dim=64, d_ff=4 * args.d_model, ffn_act="silu_glu",
        tie_embeddings=True,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=640)
    ap.add_argument("--layers", type=int, default=10)
    ap.add_argument("--vocab", type=int, default=32768)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--reservoir-k", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="artifacts/e2e_ckpt")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg = build_cfg(args)
    print(f"model: {param_count(cfg)/1e6:.1f}M params")
    shape = ShapeConfig("e2e", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    loader = StreamLoader(cfg, shape, seed=0)

    # ---- proactive SHP plan for the curation payload stream -----------
    n_docs = args.steps * args.batch
    doc_gb = args.seq * 4 / 1e9  # one example's tokens
    cm = costs.hbm_host_preset(n_docs=n_docs, k=args.reservoir_k,
                               doc_gb=doc_gb, window_seconds=3600.0)
    plan = shp.plan_placement(cm)
    pol = placement.from_plan(plan)
    print(f"SHP plan: {plan.strategy} r*/N={plan.best.r_over_n:.3f} "
          f"(writes are {shp.expected_cum_writes(n_docs-1, args.reservoir_k):.0f}"
          f" of {n_docs} docs)")
    store = tiers.TieredStore(
        pol, tiers.HotTier(args.reservoir_k, (args.seq,), dtype=jnp.int32),
        tiers.ColdTier())
    curator = TopKCurator(args.reservoir_k, store, policy=pol)

    ckpt = CheckpointManager(args.ckpt_dir, keep_latest=2, keep_best=2)
    t0 = time.time()
    report = train_loop.run(
        cfg, loader, loop=train_loop.LoopConfig(
            total_steps=args.steps, ckpt_every=max(args.steps // 4, 1),
            log_every=max(args.steps // 20, 1), lr=args.lr),
        ckpt=ckpt, curator=curator,
        on_metrics=lambda s, m: print(
            f"  step {s:4d} loss {m['loss']:.3f} "
            f"({m['step_time']*1000:.0f} ms)"))
    dt = time.time() - t0

    print(f"\ntrained {report.steps_run} steps in {dt:.0f}s "
          f"(resumed_from={report.resumed_from})")
    print(f"loss: {report.losses[0]:.3f} -> {report.losses[-1]:.3f}")
    print(f"curation: {curator.stats.as_dict()}")
    print(f"analytic E[writes]: {curator.expected_writes():.1f}")
    print(f"tier ledger: {store.ledger.as_dict()}")
    hardest = curator.finalize()
    print(f"top-{args.reservoir_k} hardest examples retained "
          f"(ids {sorted(hardest)[:6]} ...) — ready for HITL reanalysis")


if __name__ == "__main__":
    main()
