"""Multi-tenant demo: 1024+ heterogeneous top-K streams in one jitted step.

Each tenant stream has its own K, window length and cost model. The fleet
is planned proactively in one vectorized closed-form pass (the paper's r*
per stream, eq. 17/21/22), then every document batch — deliberately
shuffled across tenants — is routed, filtered and merged inside a single
jitted engine step. At the end the batched results are validated
bit-for-bit against M independent single-stream ``core.simulator`` replays,
and the per-stream ledgers are reconciled against the analytic write law.

Run: PYTHONPATH=src python examples/multi_tenant_streams.py [--streams 1024]
"""
import argparse
import time

import numpy as np

from repro.core import costs, placement, simulator
from repro.streams import StreamEngine, StreamSpec

K_CHOICES = (4, 8, 16, 32)


def make_fleet(m: int, docs: int, rng: np.random.Generator):
    """Heterogeneous tenant specs: K cycles through K_CHOICES, cost models
    jitter the HBM/host preset so every tenant gets its own r*."""
    specs = []
    for i in range(m):
        k = K_CHOICES[i % len(K_CHOICES)]
        cm = costs.hbm_host_preset(
            n_docs=docs, k=k,
            doc_gb=float(rng.uniform(1e-6, 1e-4)),
            window_seconds=float(rng.uniform(10.0, 600.0)),
            hbm_bw_gbps=819.0,
            host_link_gbps=float(rng.uniform(8.0, 64.0)),
            hbm_capacity_premium=float(rng.uniform(5.0, 500.0)),
        )
        specs.append(StreamSpec(stream_id=i, k=k, cost_model=cm))
    return specs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=1024)
    ap.add_argument("--docs", type=int, default=256,
                    help="stream/window length per tenant")
    ap.add_argument("--batch", type=int, default=32,
                    help="docs per tenant per engine step")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kernel-filter", action="store_true",
                    help="use the batched_topk Pallas pre-filter path")
    args = ap.parse_args()
    rng = np.random.default_rng(args.seed)

    specs = make_fleet(args.streams, args.docs, rng)
    t0 = time.time()
    engine = StreamEngine(specs, use_kernel_filter=args.kernel_filter)
    plan = engine.plan  # one vectorized closed-form pass, done in __init__
    print(f"planned {args.streams} streams (and built the engine) in "
          f"{time.time() - t0:.3f}s: {plan.strategy_histogram()}")
    sids = np.array([s.stream_id for s in specs])
    traces = np.stack([simulator.random_rank_trace(args.docs, rng)
                       for _ in range(args.streams)]).astype(np.float32)

    t0 = time.time()
    for t in range(0, args.docs, args.batch):
        w = min(args.batch, args.docs - t)
        mixed_sids = np.repeat(sids, w)
        mixed_dids = np.tile(np.arange(t, t + w), args.streams)
        mixed_scores = traces[:, t:t + w].reshape(-1)
        perm = rng.permutation(mixed_sids.size)  # prove the router works
        engine.ingest(mixed_sids[perm], mixed_scores[perm], mixed_dids[perm])
    dt = time.time() - t0
    total_docs = args.streams * args.docs
    print(f"ingested {total_docs} docs across {args.streams} streams "
          f"in {dt:.2f}s ({total_docs / dt:.0f} docs/s host-to-host)")

    survivors = engine.finalize()

    # --- validate: bit-match M independent single-stream replays ---------
    t0 = time.time()
    mismatches = 0
    for i, spec in enumerate(specs):
        pol = placement.Policy(r=engine.meter.rs[engine.stream_row(i)],
                               migrate_at_r=plan.migrate(i))
        sim = simulator.simulate(traces[i].astype(np.float64), spec.k, pol)
        if not np.array_equal(survivors[i], sim.survivor_ids):
            mismatches += 1
    print(f"validated vs {args.streams} independent core.simulator replays "
          f"in {time.time() - t0:.1f}s: "
          f"bit-match {args.streams - mismatches}/{args.streams}")
    if mismatches:
        raise SystemExit("batched engine diverged from single-stream replays")

    # --- reconcile per-stream ledgers vs the analytic write law ----------
    rec = engine.meter.reconcile(batch=args.batch)
    print(f"ledger reconciliation (batched write law, W={args.batch}): "
          f"fleet writes actual={rec['fleet_actual']:.0f} "
          f"expected={rec['fleet_expected']:.1f} "
          f"mean per-stream rel err={rec['mean_rel_err']:+.3%}")
    n_mig = int(np.sum(engine.meter.migrate))
    print(f"migrating streams: {n_mig} "
          f"(docs bulk-moved A->B: {int(engine.meter.migrations.sum())})")
    show = int(np.argmax(engine.meter.migrations)) if n_mig else 0
    print(f"example per-stream ledger (stream row {show}): "
          f"{engine.meter.ledger(show).as_dict()}")


if __name__ == "__main__":
    main()
