"""Constrained placement worked example: per-tier capacities and
read-path SLOs (``repro.core.constraints``).

The paper's closed forms assume unbounded tiers and free, instant reads.
Two production scenarios where that breaks:

1. **Bounded hot tier.** A local-NVMe hot tier in front of S3 holds
   C_0 ≪ K documents. The unconstrained planner would keep the first
   r* ≥ K arrivals hot; the capacity constraint forces *early demotion*
   — the hot boundary clamps to C_0 (and the migration cascade, which
   needs the whole reservoir in one tier, becomes infeasible outright).
   A scaled-down trace replay confirms the metered occupancy high-water
   mark stays under C_0.

2. **Archival retrieval SLO.** S3 Standard → Glacier Flexible Retrieval
   rents ~6x cheaper at the bottom, but a standard retrieval takes
   hours. A per-survivor expected-read-latency SLO prices that delay:
   the constrained planner pulls the cold boundary up (bounding the
   fraction of survivors parked in Glacier) or abandons the archive
   tier entirely — the SLO forces the planner *off the cheapest tier*.

Run: PYTHONPATH=src python examples/capacity_slo_cloud.py
"""
import argparse
import math

import numpy as np

from repro.core import costs, placement, shp, simulator, topology
from repro.core.constraints import (ConstraintSet, ReadLatencySLO,
                                    TierCapacity, expected_read_latency,
                                    peak_occupancy)


def fmt_plan(tag, model, plan):
    occ = peak_occupancy(plan.boundaries, model.workload.n_docs,
                         model.workload.k, plan.migrate)
    lat = expected_read_latency(plan.boundaries, model.workload.n_docs,
                                model.read_latency, plan.migrate)
    bs = ", ".join(f"{b / model.workload.n_docs:.4f}"
                   for b in plan.boundaries)
    occs = ", ".join(f"{o:,.0f}" for o in occ)
    print(f"{tag:<14}{plan.strategy:<22}${plan.total:>10.2f}  b/N=[{bs}]  "
          f"peak occ=[{occs}]  E[read lat]={lat:.3g}s")
    return occ, lat


def capacity_example(args):
    print("=" * 72)
    print("1. bounded hot tier: producer-local NVMe (C_0 ≪ K) -> S3")
    print("=" * 72)
    # NVMe next to the producer: writes are free, rental is amortized
    # hardware, but the consumer pulls reads across the network; S3 sits
    # next to the consumer and charges per-request on the write path.
    nvme = costs.TierCosts("local-nvme", put_per_doc=0.0, get_per_doc=0.0,
                           storage_per_gb_month=0.01)
    s3 = costs.TierCosts("aws-s3", put_per_doc=0.005 / 1000,
                         get_per_doc=0.0004 / 1000,
                         storage_per_gb_month=0.023)
    cap0 = args.k // 20  # the NVMe slab holds 5% of the reservoir
    topo = topology.TierTopology(tiers=(
        topology.TierSpec(nvme, xfer_out_per_gb=0.2, read_latency_s=1e-4,
                          capacity_docs=float(cap0)),
        topology.TierSpec(s3, xfer_in_per_gb=0.02, read_latency_s=0.02),
    ), name="nvme-s3")
    wl = costs.WorkloadSpec(n_docs=args.n_docs, k=args.k, doc_gb=1e-4,
                            window_months=1.0)
    model = topo.cost_model(wl)
    # an explicit TierCapacity(0, inf) *overrides* the topology-declared
    # C_0 (declarations otherwise always apply) — the what-if baseline
    unconstrained = shp.plan_placement_ntier(
        model, constraints=ConstraintSet(TierCapacity(0, math.inf)))
    constrained = shp.plan_placement_ntier(model)  # topology-declared C_0
    fmt_plan("unconstrained", model, unconstrained)
    occ, _ = fmt_plan("C_0=%d" % cap0, model, constrained)
    assert occ[0] <= cap0 * (1 + 1e-9)
    assert constrained.boundaries[0] <= cap0
    assert unconstrained.boundaries[0] > args.k > constrained.boundaries[0]
    print(f"-> early demotion: the unconstrained plan holds the first "
          f"{unconstrained.boundaries[0]:,.0f}\n   arrivals hot (the whole "
          f"reservoir passes through NVMe); C_0={cap0:,} < K\n   clamps the "
          f"hot boundary to {constrained.boundaries[0]:,.0f} docs "
          f"(+${constrained.total - unconstrained.total:.2f} expected cost)")
    return model, constrained, cap0


def slo_example(args):
    print()
    print("=" * 72)
    print("2. archival SLO: S3 Standard -> Glacier Flexible (hours to read)")
    print("=" * 72)
    topo = topology.aws_archive_tiering()
    wl = costs.WorkloadSpec(n_docs=args.n_docs, k=args.k, doc_gb=1e-3,
                            window_months=6.0)
    model = topo.cost_model(wl)
    glacier_lat = model.read_latency[-1]
    print(f"tier read latencies: {model.read_latency.tolist()} s")
    unconstrained = shp.plan_placement_ntier(model)
    fmt_plan("no SLO", model, unconstrained)
    for slo in (glacier_lat / 4, 60.0):
        plan = shp.plan_placement_ntier(
            model, constraints=ConstraintSet(ReadLatencySLO(slo)))
        _, lat = fmt_plan(f"SLO={slo:g}s", model, plan)
        assert lat <= slo * (1 + 1e-9)
    print("-> the SLO caps the fraction of survivors parked in Glacier; a "
          "tight\n   SLO walks the plan all the way back to S3 Standard")


def reconcile(model, plan, cap0, args):
    """Scaled-down trace replay: the metered occupancy high-water mark must
    respect the capacity the planner was told about."""
    wl = model.workload
    scale = args.sim_docs / wl.n_docs
    k_sim = max(int(wl.k * scale), 8)
    cap_sim = max(int(cap0 * scale), 1)
    sim_model = model.replace(workload=costs.WorkloadSpec(
        n_docs=args.sim_docs, k=k_sim, doc_gb=wl.doc_gb,
        window_months=wl.window_months))
    plan_sim = shp.plan_placement_ntier(
        sim_model, constraints=ConstraintSet(TierCapacity(0, cap_sim)))
    pol = placement.Policy(boundaries=plan_sim.boundaries,
                           migrate_at_r=plan_sim.migrate)
    rng = np.random.default_rng(0)
    cset = ConstraintSet(TierCapacity(0, cap_sim))
    print(f"\ntrace replay (N={args.sim_docs}, K={k_sim}, C_0={cap_sim}, "
          f"{args.trials} trials):")
    worst = np.zeros(sim_model.t, np.int64)
    for _ in range(args.trials):
        res = simulator.simulate(
            simulator.random_rank_trace(args.sim_docs, rng), k_sim, pol,
            sim_model)
        worst = np.maximum(worst, res.occupancy_hwm_per_tier)
        report = res.check_constraints(cset, sim_model)
        assert report["ok"], report
    print(f"occupancy high-water marks {worst.tolist()} "
          f"(hot cap {cap_sim}) — no violations at reconciliation")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=int(1e7))
    ap.add_argument("--k", type=int, default=int(1e5))
    ap.add_argument("--sim-docs", type=int, default=20_000)
    ap.add_argument("--trials", type=int, default=3)
    args = ap.parse_args()
    model, constrained, cap0 = capacity_example(args)
    slo_example(args)
    reconcile(model, constrained, cap0, args)


if __name__ == "__main__":
    main()
