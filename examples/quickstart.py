"""Quickstart: the paper's optimization end-to-end in 60 seconds.

1. Build a two-tier cost model (Table I prices).
2. Get the closed-form placement plan (r*, strategy) — eqs. 17/21/22.
3. Validate it against a trace-driven simulation.
4. Run a tiny LM train loop where the top-K most interesting examples are
   retained across a hot/cold TieredStore under that plan.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import costs, placement, shp, simulator, tiers
from repro.data.curation import TopKCurator


def main():
    # ---- 1-2: analytic plan -------------------------------------------
    cm = costs.case_study_1()
    plan = shp.plan_placement(cm)
    print("== Case study 1 (AWS S3 -> Azure Blob) ==")
    print(f"  strategy: {plan.strategy}")
    print(f"  r*/N    : {plan.best.r_over_n:.4f} (paper: 0.41233169)")
    print(f"  E[cost] : ${plan.best.total:.2f} (paper: 35.19)")
    for c in plan.candidates:
        print(f"    candidate {c.strategy:28s} ${c.total:8.2f}")

    # ---- 3: trace-driven validation (paper Fig. 8) --------------------
    n, k = 50_000, 500
    small = cm.replace(workload=costs.WorkloadSpec(
        n_docs=n, k=k, doc_gb=cm.workload.doc_gb,
        window_months=cm.workload.window_months))
    pol = placement.optimal_policy(small)
    rng = np.random.default_rng(0)
    sim = simulator.simulate(simulator.grn_entropy_trace(n, rng), k, pol,
                             small, storage_bound=True)
    analytic = shp.cost_no_migration(small, pol.r, exact=True).total
    print("\n== Trace-driven validation ==")
    print(f"  simulated cost ${sim.cost_total:.4f} vs analytic ${analytic:.4f}")
    print(f"  writes A/B: {sim.writes_per_tier.tolist()}  "
          f"evictions: {sim.evictions}")

    # ---- 4: top-K curation inside a (tiny) train loop ------------------
    print("\n== Top-K curation during training ==")
    import jax
    from repro import configs
    from repro.configs.base import ShapeConfig
    from repro.data.pipeline import StreamLoader
    from repro.runtime import steps as steps_mod

    cfg = configs.get_config("llama3.2-1b", reduced=True)
    shape = ShapeConfig("quick", seq_len=32, global_batch=8, kind="train")
    loader = StreamLoader(cfg, shape, seed=0)
    kq = 16
    total = 20 * shape.global_batch
    store = tiers.TieredStore(placement.Policy(r=total // 2),
                              tiers.HotTier(kq, (shape.seq_len,), dtype=jax.numpy.int32),
                              tiers.ColdTier())
    cur = TopKCurator(kq, store, policy=store.policy)
    state = steps_mod.init_train_state(cfg, jax.random.PRNGKey(0),
                                       reservoir_k=kq)
    step_fn = jax.jit(lambda s, b: steps_mod.train_step(s, b, cfg))
    for step in range(20):
        batch = jax.tree.map(jax.numpy.asarray, loader.batch_for_step(step))
        state, metrics = step_fn(state, batch)
        cur.observe_batch(np.asarray(batch["example_ids"]),
                          np.asarray(metrics["per_example_nll"]),
                          np.asarray(batch["tokens"]))
    print(f"  observed {cur.stats.observed} examples; "
          f"writes {cur.stats.writes} "
          f"(analytic E[writes] {cur.expected_writes():.1f})")
    print(f"  device reservoir == host curator: "
          f"{sorted(int(i) for i in np.asarray(state.reservoir.ids)) == sorted(cur.survivor_ids().tolist())}")
    hard = cur.finalize()
    print(f"  retained top-{kq} hardest examples: {sorted(hard)[:8]} ...")
    print(f"  tier ledger: {store.ledger.as_dict()}")


if __name__ == "__main__":
    main()
