"""Million-stream sharded serving demo: plan, ingest, finalize one
top-K retention window for 1M tenant streams with the fleet axis
shard_map-ped across devices.

Phases (all on a forced multi-device CPU mesh — no hardware needed):

1. **Plan** — one sharded ``core.shp_jax`` candidate-grid solve over all
   M streams' 3-tier cost arrays, then cross-shard water-filling
   (``streams.planner.waterfill`` → psum bisection) of a fleet-shared
   hot-tier budget, and a constrained sharded re-solve of only the
   streams the budget actually binds.
2. **Ingest** — a ``StreamEngine`` over the mesh: reservoir, metrics and
   drift state live device-resident and row-sharded; chunks stream
   through the async double-buffered ``ingest_chunks`` loop (chunk t+1's
   host→device transfer overlaps chunk t's compute, buffers donated).
3. **Finalize** — final top-K reads metered per stream; the obs
   snapshot reports fleet-global (cross-shard aggregated) counters.

Beside the million small-K exact reservoirs the window co-runs a pack of
huge-K ``engine="logmem"`` tenants (K = 65536 by default): O(log K)
device state advanced by the same sharded step, with the admit counts
asserted against the closed-form write law within the backend's
1−O(1/√K) slack and the bytes-per-stream advantage checked >= 8x.

Run:
  PYTHONPATH=src python examples/million_streams.py [--streams 1000000]
  PYTHONPATH=src python examples/million_streams.py --ci   # 64k, CI scale

``--devices N`` forces an N-device CPU mesh via
``--xla_force_host_platform_device_count`` (set before jax imports);
``--devices 1`` runs the same window unsharded for comparison.
"""
import argparse
import json
import os
import sys
import time


def _pre_parse_devices(argv):
    """--devices must take effect before jax is imported."""
    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--devices", type=int, default=8)
    args, _ = ap.parse_known_args(argv)
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={args.devices}"
        ).strip()
    return args.devices


_DEVICES = _pre_parse_devices(sys.argv[1:])

import numpy as np  # noqa: E402

import jax  # noqa: E402

from repro.core import constraints as cons  # noqa: E402
from repro.core import shp_jax  # noqa: E402
from repro.obs import Observability, ObsConfig  # noqa: E402
from repro.parallel import fleet  # noqa: E402
from repro.streams import StreamEngine, StreamSpec, logmem, planner  # noqa: E402


def fleet_cost_arrays(rng, m, n_docs, k):
    """Per-stream 3-tier (hot/warm/cold) cost arrays: write-cheap
    read-expensive hot tier, the reverse cold, jittered per stream so
    the fleet plan is genuinely heterogeneous."""
    jit = lambda lo, hi: rng.uniform(lo, hi, m)  # noqa: E731
    cw = np.stack([jit(0.8, 1.2) * 1e-6, jit(0.8, 1.2) * 2e-5,
                   jit(0.8, 1.2) * 8e-5], axis=1)
    cr = np.stack([jit(0.8, 1.2) * 2.7e-4, jit(0.8, 1.2) * 4e-5,
                   jit(0.8, 1.2) * 1e-6], axis=1)
    cs = np.stack([jit(0.8, 1.2) * 2.5e-6, jit(0.8, 1.2) * 1e-6,
                   jit(0.8, 1.2) * 2.5e-7], axis=1)
    n = np.full(m, float(n_docs))
    kv = np.full(m, float(k))
    rpw = rng.uniform(0.5, 4.0, m)
    return cw, cr, cs, n, kv, rpw


def plan_phase(mesh, rng, m, n_docs, k, hot_frac):
    """Sharded fleet plan + shared hot-tier water-filling."""
    cw, cr, cs, n, kv, rpw = fleet_cost_arrays(rng, m, n_docs, k)
    t0 = time.time()
    with fleet.use_fleet_mesh(mesh):
        plan = shp_jax.plan_ntier_arrays_jax(cw, cr, cs, n, kv, rpw)
    t_solve = time.time() - t0
    bounds, mig = plan["bounds"], plan["migrate"]
    desired = cons.peak_occupancy_arrays(bounds, n, kv, mig)[:, 0]
    budget = float(desired.sum()) * hot_frac
    t0 = time.time()
    grants = planner.waterfill(desired, budget, mesh=mesh)
    t_wf = time.time() - t0
    binding = grants < desired - 1e-9
    t0 = time.time()
    if binding.any():
        idx = np.flatnonzero(binding)
        cap = np.full((idx.size, 3), np.inf)
        cap[:, 0] = grants[idx]
        with fleet.use_fleet_mesh(mesh):
            re = shp_jax.plan_ntier_arrays_jax(
                cw[idx], cr[idx], cs[idx], n[idx], kv[idx], rpw[idx],
                cap=cap)
        bounds = bounds.copy()
        mig = mig.copy()
        bounds[idx] = re["bounds"]
        mig[idx] = re["migrate"]
    t_resolve = time.time() - t0
    hot_occ = cons.peak_occupancy_arrays(bounds, n, kv, mig)[:, 0]
    assert hot_occ.sum() <= budget * (1 + 1e-9) + 1e-6, \
        "hot-tier budget oversubscribed after re-solve"
    return {
        "bounds": bounds, "migrate": mig,
        "stats": {
            "solve_s": round(t_solve, 3),
            "waterfill_s": round(t_wf, 3),
            "resolve_s": round(t_resolve, 3),
            "binding_streams": int(binding.sum()),
            "hot_budget_docs": budget,
            "hot_peak_docs": float(hot_occ.sum()),
        },
    }


def dense_chunks(rng, m, w, n_chunks, lm=0, lw=0):
    """Generator of ingest_dense-shaped chunks: the main uniform-K exact
    bucket, plus (when ``lm`` > 0) a second pair for the huge-K logmem
    bucket — wider chunks, so the big-K tenants get past their admit-all
    warmup inside the same window. Produced lazily so chunk t+1's
    materialization and host→device copy overlap chunk t's sharded
    step."""
    for c in range(n_chunks):
        sc = rng.standard_normal((m, w)).astype(np.float32)
        ids = np.tile(np.arange(c * w, (c + 1) * w, dtype=np.int32),
                      (m, 1))
        pairs = [(sc, ids)]
        if lm:
            ls = rng.standard_normal((lm, lw)).astype(np.float32)
            lids = np.tile(np.arange(c * lw, (c + 1) * lw, dtype=np.int32),
                           (lm, 1))
            pairs.append((ls, lids))
        yield pairs


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--streams", type=int, default=1_000_000)
    ap.add_argument("--docs", type=int, default=256,
                    help="docs per stream in the window")
    ap.add_argument("--chunk", type=int, default=16,
                    help="docs per stream per ingest chunk")
    ap.add_argument("--topk", type=int, default=8)
    ap.add_argument("--hot-frac", type=float, default=0.6,
                    help="fleet-shared hot-tier budget as a fraction of "
                         "the unconstrained plan's hot occupancy")
    ap.add_argument("--meter", action="store_true",
                    help="keep the per-stream host ledgers during ingest "
                         "(the default is pure-throughput: device metrics "
                         "only, ledgers at finalize)")
    ap.add_argument("--logmem-streams", type=int, default=None,
                    help="huge-K O(log K) tenants co-run beside the main "
                         "fleet (default: 64 under --ci, else 0)")
    ap.add_argument("--logmem-k", type=int, default=65_536,
                    help="reservoir width of the logmem tenants")
    ap.add_argument("--logmem-chunk", type=int, default=8_192,
                    help="docs per logmem stream per ingest chunk")
    ap.add_argument("--ci", action="store_true",
                    help="CI scale: 64k streams + 64 K=65536 logmem "
                         "tenants")
    ap.add_argument("--out", default="bench_out/million_streams.json")
    args = ap.parse_args()
    if args.ci:
        args.streams = min(args.streams, 64_000)
    lm = (args.logmem_streams if args.logmem_streams is not None
          else (64 if args.ci else 0))
    lk, lw = args.logmem_k, args.logmem_chunk

    mesh = fleet.fleet_mesh(args.devices) if args.devices > 1 else None
    shards = fleet.n_shards(mesh)
    m, k = args.streams, args.topk
    if lm and lm % max(shards, 1):
        lm = (-(-lm // shards)) * shards  # keep the logmem bucket even
    print(f"{m} streams on {jax.local_device_count()} devices "
          f"({shards} shards)"
          + (f" + {lm} logmem tenants at K={lk}" if lm else ""))
    rng = np.random.default_rng(0)

    # --- phase 1: sharded plan + cross-shard water-filling ---------------
    plan = plan_phase(mesh, rng, m, args.docs, k, args.hot_frac)
    st = plan["stats"]
    print(f"plan: solve {st['solve_s']}s, waterfill {st['waterfill_s']}s, "
          f"re-solve of {st['binding_streams']} binding streams "
          f"{st['resolve_s']}s; hot occupancy {st['hot_peak_docs']:.0f} "
          f"<= budget {st['hot_budget_docs']:.0f}")

    # --- phase 2: sharded double-buffered ingest -------------------------
    t0 = time.time()
    specs = [StreamSpec(stream_id=i, k=k, boundaries=bt, migrate=bool(mg))
             for i, (bt, mg) in enumerate(zip(
                 map(tuple, plan["bounds"]), plan["migrate"]))]
    # huge-K tenants: O(log K) device state, admission by threshold
    # compare — the same fleet step advances both buckets
    specs += [StreamSpec(stream_id=m + i, k=lk, r=float(4 * lk),
                         engine="logmem") for i in range(lm)]
    obs = Observability(ObsConfig(residuals=False))
    eng = StreamEngine(specs, obs=obs, mesh=mesh)
    t_build = time.time() - t0
    n_chunks = args.docs // args.chunk
    t0 = time.time()
    done = eng.ingest_chunks(
        dense_chunks(rng, m, args.chunk, n_chunks, lm, lw),
        meter=args.meter)
    t_ingest = time.time() - t0
    docs = (m * args.chunk + lm * lw) * done
    print(f"ingest: {done} chunks, {docs / 1e6:.1f}M docs in "
          f"{t_ingest:.2f}s ({docs / t_ingest / 1e6:.2f}M docs/s)")

    # --- phase 3: finalize + fleet-global obs ----------------------------
    t0 = time.time()
    for bi, b in enumerate(eng.buckets):
        if b.engine == "logmem":
            continue  # no device-resident ids to read back
        eng.meter.record_reads(eng._global_rows[bi],
                               np.asarray(eng._states[bi].ids)[:b.m])
    t_final = time.time() - t0
    snap = eng.obs_snapshot()
    em = snap["engine"]
    assert em["docs"] == docs, (em["docs"], docs)
    assert int(eng.meter.reads.sum()) == m * k
    print(f"finalize: {t_final:.2f}s; fleet-global obs: "
          f"docs={em['docs']} admits={em['admits']} "
          f"evictions={em['evictions']} chunks={em['chunks']}")

    lm_stats = None
    if lm:
        lb = next(bi for bi, b in enumerate(eng.buckets)
                  if b.engine == "logmem")
        admits = np.asarray(eng._states[lb].admits, np.float64)[:lm]
        n_lm = lw * done
        law = float(logmem.expected_admits(np.asarray([n_lm]), lk)[0])
        slack = logmem.law_slack(lk)
        admit_ratio = float(admits.mean()) / law
        bps = logmem.state_bytes_per_stream(eng._states[lb])
        exact_bps = logmem.exact_bytes_per_stream(lk)
        assert abs(admit_ratio - 1.0) <= 3.0 * slack, \
            (f"logmem admits {admit_ratio:.4f}x law, beyond the "
             f"{3.0 * slack:.4f} slack budget")
        assert exact_bps / bps >= 8.0, (bps, exact_bps)
        lm_stats = {
            "streams": lm, "k": lk, "docs_per_stream": n_lm,
            "admits_mean": float(admits.mean()),
            "expected_admits": law,
            "admit_ratio": round(admit_ratio, 5),
            "law_slack": round(slack, 5),
            "bytes_per_stream": round(bps, 1),
            "exact_bytes_per_stream": exact_bps,
            "memory_ratio": round(exact_bps / bps, 1),
        }
        print(f"logmem: {lm} tenants at K={lk}: admits "
              f"{admit_ratio:.4f}x law (slack {slack:.4f}), "
              f"{bps:.0f} B/stream vs {exact_bps:.0f} exact "
              f"({exact_bps / bps:.0f}x leaner)")

    out = {
        "streams": m, "devices": jax.local_device_count(),
        "shards": shards, "docs_per_stream": args.docs,
        "chunk": args.chunk, "topk": k,
        "plan": st,
        "engine_build_s": round(t_build, 3),
        "ingest_s": round(t_ingest, 3),
        "ingest_docs_per_s": round(docs / t_ingest, 1),
        "finalize_s": round(t_final, 3),
        "obs_engine": em,
        "meter": snap["meter"],
        "logmem": lm_stats,
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
