"""Million-stream sharded serving demo: plan, ingest, finalize one
top-K retention window for 1M tenant streams with the fleet axis
shard_map-ped across devices.

Phases (all on a forced multi-device CPU mesh — no hardware needed):

1. **Plan** — one sharded ``core.shp_jax`` candidate-grid solve over all
   M streams' 3-tier cost arrays, then cross-shard water-filling
   (``streams.planner.waterfill`` → psum bisection) of a fleet-shared
   hot-tier budget, and a constrained sharded re-solve of only the
   streams the budget actually binds.
2. **Ingest** — a ``StreamEngine`` over the mesh: reservoir, metrics and
   drift state live device-resident and row-sharded; chunks stream
   through the async double-buffered ``ingest_chunks`` loop (chunk t+1's
   host→device transfer overlaps chunk t's compute, buffers donated).
3. **Finalize** — final top-K reads metered per stream; the obs
   snapshot reports fleet-global (cross-shard aggregated) counters.

Run:
  PYTHONPATH=src python examples/million_streams.py [--streams 1000000]
  PYTHONPATH=src python examples/million_streams.py --ci   # 64k, CI scale

``--devices N`` forces an N-device CPU mesh via
``--xla_force_host_platform_device_count`` (set before jax imports);
``--devices 1`` runs the same window unsharded for comparison.
"""
import argparse
import json
import os
import sys
import time


def _pre_parse_devices(argv):
    """--devices must take effect before jax is imported."""
    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--devices", type=int, default=8)
    args, _ = ap.parse_known_args(argv)
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={args.devices}"
        ).strip()
    return args.devices


_DEVICES = _pre_parse_devices(sys.argv[1:])

import numpy as np  # noqa: E402

import jax  # noqa: E402

from repro.core import constraints as cons  # noqa: E402
from repro.core import shp_jax  # noqa: E402
from repro.obs import Observability, ObsConfig  # noqa: E402
from repro.parallel import fleet  # noqa: E402
from repro.streams import StreamEngine, StreamSpec, planner  # noqa: E402


def fleet_cost_arrays(rng, m, n_docs, k):
    """Per-stream 3-tier (hot/warm/cold) cost arrays: write-cheap
    read-expensive hot tier, the reverse cold, jittered per stream so
    the fleet plan is genuinely heterogeneous."""
    jit = lambda lo, hi: rng.uniform(lo, hi, m)  # noqa: E731
    cw = np.stack([jit(0.8, 1.2) * 1e-6, jit(0.8, 1.2) * 2e-5,
                   jit(0.8, 1.2) * 8e-5], axis=1)
    cr = np.stack([jit(0.8, 1.2) * 2.7e-4, jit(0.8, 1.2) * 4e-5,
                   jit(0.8, 1.2) * 1e-6], axis=1)
    cs = np.stack([jit(0.8, 1.2) * 2.5e-6, jit(0.8, 1.2) * 1e-6,
                   jit(0.8, 1.2) * 2.5e-7], axis=1)
    n = np.full(m, float(n_docs))
    kv = np.full(m, float(k))
    rpw = rng.uniform(0.5, 4.0, m)
    return cw, cr, cs, n, kv, rpw


def plan_phase(mesh, rng, m, n_docs, k, hot_frac):
    """Sharded fleet plan + shared hot-tier water-filling."""
    cw, cr, cs, n, kv, rpw = fleet_cost_arrays(rng, m, n_docs, k)
    t0 = time.time()
    with fleet.use_fleet_mesh(mesh):
        plan = shp_jax.plan_ntier_arrays_jax(cw, cr, cs, n, kv, rpw)
    t_solve = time.time() - t0
    bounds, mig = plan["bounds"], plan["migrate"]
    desired = cons.peak_occupancy_arrays(bounds, n, kv, mig)[:, 0]
    budget = float(desired.sum()) * hot_frac
    t0 = time.time()
    grants = planner.waterfill(desired, budget, mesh=mesh)
    t_wf = time.time() - t0
    binding = grants < desired - 1e-9
    t0 = time.time()
    if binding.any():
        idx = np.flatnonzero(binding)
        cap = np.full((idx.size, 3), np.inf)
        cap[:, 0] = grants[idx]
        with fleet.use_fleet_mesh(mesh):
            re = shp_jax.plan_ntier_arrays_jax(
                cw[idx], cr[idx], cs[idx], n[idx], kv[idx], rpw[idx],
                cap=cap)
        bounds = bounds.copy()
        mig = mig.copy()
        bounds[idx] = re["bounds"]
        mig[idx] = re["migrate"]
    t_resolve = time.time() - t0
    hot_occ = cons.peak_occupancy_arrays(bounds, n, kv, mig)[:, 0]
    assert hot_occ.sum() <= budget * (1 + 1e-9) + 1e-6, \
        "hot-tier budget oversubscribed after re-solve"
    return {
        "bounds": bounds, "migrate": mig,
        "stats": {
            "solve_s": round(t_solve, 3),
            "waterfill_s": round(t_wf, 3),
            "resolve_s": round(t_resolve, 3),
            "binding_streams": int(binding.sum()),
            "hot_budget_docs": budget,
            "hot_peak_docs": float(hot_occ.sum()),
        },
    }


def dense_chunks(rng, m, w, n_chunks):
    """Generator of ingest_dense-shaped chunks (one uniform-K bucket):
    produced lazily so chunk t+1's materialization and host→device copy
    overlap chunk t's sharded step."""
    for c in range(n_chunks):
        sc = rng.standard_normal((m, w)).astype(np.float32)
        ids = np.tile(np.arange(c * w, (c + 1) * w, dtype=np.int32),
                      (m, 1))
        yield [(sc, ids)]


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--streams", type=int, default=1_000_000)
    ap.add_argument("--docs", type=int, default=256,
                    help="docs per stream in the window")
    ap.add_argument("--chunk", type=int, default=16,
                    help="docs per stream per ingest chunk")
    ap.add_argument("--topk", type=int, default=8)
    ap.add_argument("--hot-frac", type=float, default=0.6,
                    help="fleet-shared hot-tier budget as a fraction of "
                         "the unconstrained plan's hot occupancy")
    ap.add_argument("--meter", action="store_true",
                    help="keep the per-stream host ledgers during ingest "
                         "(the default is pure-throughput: device metrics "
                         "only, ledgers at finalize)")
    ap.add_argument("--ci", action="store_true",
                    help="CI scale: 64k streams")
    ap.add_argument("--out", default="bench_out/million_streams.json")
    args = ap.parse_args()
    if args.ci:
        args.streams = min(args.streams, 64_000)

    mesh = fleet.fleet_mesh(args.devices) if args.devices > 1 else None
    shards = fleet.n_shards(mesh)
    m, k = args.streams, args.topk
    print(f"{m} streams on {jax.local_device_count()} devices "
          f"({shards} shards)")
    rng = np.random.default_rng(0)

    # --- phase 1: sharded plan + cross-shard water-filling ---------------
    plan = plan_phase(mesh, rng, m, args.docs, k, args.hot_frac)
    st = plan["stats"]
    print(f"plan: solve {st['solve_s']}s, waterfill {st['waterfill_s']}s, "
          f"re-solve of {st['binding_streams']} binding streams "
          f"{st['resolve_s']}s; hot occupancy {st['hot_peak_docs']:.0f} "
          f"<= budget {st['hot_budget_docs']:.0f}")

    # --- phase 2: sharded double-buffered ingest -------------------------
    t0 = time.time()
    specs = [StreamSpec(stream_id=i, k=k, boundaries=bt, migrate=bool(mg))
             for i, (bt, mg) in enumerate(zip(
                 map(tuple, plan["bounds"]), plan["migrate"]))]
    obs = Observability(ObsConfig(residuals=False))
    eng = StreamEngine(specs, obs=obs, mesh=mesh)
    t_build = time.time() - t0
    n_chunks = args.docs // args.chunk
    t0 = time.time()
    done = eng.ingest_chunks(
        dense_chunks(rng, m, args.chunk, n_chunks), meter=args.meter)
    t_ingest = time.time() - t0
    docs = m * args.chunk * done
    print(f"ingest: {done} chunks, {docs / 1e6:.1f}M docs in "
          f"{t_ingest:.2f}s ({docs / t_ingest / 1e6:.2f}M docs/s)")

    # --- phase 3: finalize + fleet-global obs ----------------------------
    t0 = time.time()
    for bi, b in enumerate(eng.buckets):
        eng.meter.record_reads(eng._global_rows[bi],
                               np.asarray(eng._states[bi].ids)[:b.m])
    t_final = time.time() - t0
    snap = eng.obs_snapshot()
    em = snap["engine"]
    assert em["docs"] == docs, (em["docs"], docs)
    assert int(eng.meter.reads.sum()) == m * k
    print(f"finalize: {t_final:.2f}s; fleet-global obs: "
          f"docs={em['docs']} admits={em['admits']} "
          f"evictions={em['evictions']} chunks={em['chunks']}")

    out = {
        "streams": m, "devices": jax.local_device_count(),
        "shards": shards, "docs_per_stream": args.docs,
        "chunk": args.chunk, "topk": k,
        "plan": st,
        "engine_build_s": round(t_build, 3),
        "ingest_s": round(t_ingest, 3),
        "ingest_docs_per_s": round(docs / t_ingest, 1),
        "finalize_s": round(t_final, 3),
        "obs_engine": em,
        "meter": snap["meter"],
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
