"""Serving example: batched prefill+decode with top-K request logging.

A small LM serves batches of requests; every completed request is scored by
predictive entropy (uncertainty), and the top-K most "interesting" requests
per window are retained in tiered storage (hot ring buffer → cold store) at
the placement the SHP plan chose — exactly the paper's workflow with the
serving fleet as the producer and offline analysis as the consumer.

Multi-tenant mode (``--tenants M``): requests are interleaved across M
tenant streams, each with its own K, cost model and tier topology (every
third tenant places across a 3-tier HBM → DRAM → disk hierarchy, the rest
across the 2-tier HBM → host preset); retention then runs through the
batched ``repro.streams`` engine — the heterogeneous fleet is planned in a
few vectorized passes and every scored batch advances all tenants inside
one jitted step.

``--mesh N`` shards the tenant fleet axis across N forced CPU devices
(``repro.parallel``): the engine step, metrics, and planner solves then
run shard_map-ped, and ``--obs-out`` artifacts report the cross-shard
aggregated counters.

Run: PYTHONPATH=src python examples/serve_topk.py [--requests 64]
"""
import argparse
import os
import signal
import sys
import time


def _pre_parse_mesh(argv):
    """--mesh must force the device count before jax is imported."""
    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--mesh", type=int, default=1)
    args, _ = ap.parse_known_args(argv)
    flags = os.environ.get("XLA_FLAGS", "")
    if (args.mesh > 1
            and "--xla_force_host_platform_device_count" not in flags):
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={args.mesh}"
        ).strip()


_pre_parse_mesh(sys.argv[1:])

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import configs  # noqa: E402
from repro.core import costs, placement, shp, tiers  # noqa: E402
from repro.data.curation import TopKCurator  # noqa: E402
from repro.models import lm  # noqa: E402


def make_tenant_engine(tenants: int, requests: int, topk: int, doc_gb: float,
                       obs=None, mesh=None):
    """Heterogeneous per-tenant retention: K alternates, cost models jitter
    the HBM presets, every third tenant gets a 3-tier HBM → DRAM → disk
    topology, and the fleet planner picks each tenant's boundary vector.
    With ``mesh`` the tenant axis shards across it (``repro.parallel``)."""
    from repro.core import topology
    from repro.streams import StreamEngine, StreamSpec
    # ceil: when tenants doesn't divide requests, the first tenants get one
    # extra doc — the cost model must cover their longer stream
    n_per = -(-requests // tenants)
    if requests // tenants < 2:
        raise SystemExit(f"need requests >= 2*tenants, got {requests} "
                         f"requests for {tenants} tenants")
    specs = []
    for t in range(tenants):
        k = max(1, min(topk if t % 2 == 0 else topk // 2, n_per - 1))
        window = 30.0 * (1 + t % 4)
        if t % 3 == 2:
            cm = topology.hbm_dram_disk_preset(
                n_docs=n_per, k=k, doc_gb=doc_gb, window_seconds=window)
        else:
            cm = costs.hbm_host_preset(n_docs=n_per, k=k, doc_gb=doc_gb,
                                       window_seconds=window)
        specs.append(StreamSpec(stream_id=t, k=k, cost_model=cm))
    return StreamEngine(specs, obs=obs, mesh=mesh), specs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=12)
    ap.add_argument("--topk", type=int, default=8)
    ap.add_argument("--tenants", type=int, default=1,
                    help="number of tenant streams; with >1, retention is "
                         "routed through the multi-tenant repro.streams "
                         "engine (heterogeneous per-tenant K, cost model, "
                         "and tier depth — every third tenant plans a "
                         "3-tier HBM->DRAM->disk hierarchy); requires "
                         "--requests >= 2*tenants")
    ap.add_argument("--obs-out", default=None, metavar="DIR",
                    help="enable the repro.obs telemetry layer and write "
                         "metrics.json / metrics.prom (Prometheus text "
                         "exposition) / events.jsonl artifacts to DIR")
    ap.add_argument("--obs-port", type=int, default=None, metavar="PORT",
                    help="serve live /metrics (Prometheus) and /snapshot "
                         "(JSON) from the running engine on this port "
                         "(0 = ephemeral); implies the obs layer with "
                         "cost attribution on")
    ap.add_argument("--obs-hold", type=float, default=0.0, metavar="SEC",
                    help="stretch the serving loop over at least SEC "
                         "seconds so a scraper can observe the live "
                         "counters advancing (CI smoke)")
    ap.add_argument("--mesh", type=int, default=1,
                    help="shard the tenant fleet across an N-device CPU "
                         "mesh (forced via XLA_FLAGS before jax loads); "
                         "requires --tenants > 1")
    ap.add_argument("--ckpt-dir", default=None, metavar="DIR",
                    help="crash-consistent fleet checkpointing "
                         "(repro.resilience; requires --tenants > 1): "
                         "write chunk-boundary checkpoints to DIR, plus a "
                         "final blocking checkpoint on exit and on "
                         "SIGTERM/SIGINT")
    ap.add_argument("--ckpt-every", type=int, default=4, metavar="N",
                    help="checkpoint every N ingested chunks (0 = final "
                         "checkpoint only)")
    args = ap.parse_args()

    mesh = None
    if args.mesh > 1:
        if args.tenants <= 1:
            raise SystemExit("--mesh requires --tenants > 1")
        from repro.parallel import fleet
        mesh = fleet.fleet_mesh(args.mesh)
        print(f"fleet mesh: {args.mesh} devices, tenant axis sharded")

    obs = obs_server = None
    if args.obs_out is not None or args.obs_port is not None:
        from repro.obs import Observability, ObsConfig
        # the live dashboard prices the fleet as it serves — cost
        # attribution rides along whenever the endpoint is requested
        obs = Observability(ObsConfig(costs=args.obs_port is not None))
    if args.obs_port is not None:
        from repro.obs import http as obs_http
        obs_server = obs_http.serve(obs, port=args.obs_port)
        print(f"obs endpoint: {obs_server.url}/metrics "
              f"{obs_server.url}/snapshot", flush=True)

    cfg = configs.get_config(args.arch, reduced=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    print(f"serving reduced {args.arch}: vocab={cfg.vocab_size}")

    doc_gb = (args.prompt_len + args.gen_len) * 4 / 1e9
    curator = engine = None
    if args.tenants > 1:
        engine, tenant_specs = make_tenant_engine(
            args.tenants, args.requests, args.topk, doc_gb, obs=obs,
            mesh=mesh)
        print(f"multi-tenant retention: {args.tenants} streams, "
              f"fleet plan {engine.plan.strategy_histogram()}")
    else:
        # proactive placement for the request-log stream
        cm = costs.hbm_host_preset(n_docs=args.requests, k=args.topk,
                                   doc_gb=doc_gb, window_seconds=60.0)
        plan = shp.plan_placement(cm)
        pol = placement.from_plan(plan)
        print(f"SHP plan for request log: {plan.strategy} "
              f"r*/N={plan.best.r_over_n:.3f}")
        store = tiers.TieredStore(
            pol, tiers.HotTier(args.topk, (args.prompt_len + args.gen_len,),
                               dtype=jnp.int32), tiers.ColdTier())
        curator = TopKCurator(args.topk, store, policy=pol)

    checkpointer = None
    if args.ckpt_dir is not None:
        if engine is None:
            raise SystemExit("--ckpt-dir requires --tenants > 1")
        from repro.resilience import FleetCheckpointer
        checkpointer = FleetCheckpointer(args.ckpt_dir,
                                         every=args.ckpt_every)
        engine.attach_checkpointer(checkpointer)
        print(f"checkpointing to {args.ckpt_dir} "
              f"(every {args.ckpt_every} chunks)")

    # Graceful shutdown: SIGTERM/SIGINT only request a stop — the loop
    # finishes its in-flight batch, then the normal teardown runs (final
    # blocking checkpoint, obs artifacts, endpoint drain).
    stop = {"signal": None}

    def _request_stop(signum, frame):
        stop["signal"] = signum

    for s in (signal.SIGTERM, signal.SIGINT):
        signal.signal(s, _request_stop)

    prefill = jax.jit(lambda p, b, c: lm.prefill(p, cfg, b, c))
    step = jax.jit(lambda p, t, c: lm.decode_step(p, cfg, t, c))
    rng = np.random.default_rng(0)

    served = 0
    n_batches = -(-args.requests // args.batch)
    t0 = time.time()
    while served < args.requests and stop["signal"] is None:
        b = min(args.batch, args.requests - served)
        prompts = rng.integers(0, cfg.vocab_size, (b, args.prompt_len))
        cache = lm.init_cache(cfg, b, args.prompt_len + args.gen_len + 1)
        logits, cache = prefill(params,
                                {"tokens": jnp.asarray(prompts, jnp.int32)},
                                cache)
        toks = [jnp.argmax(logits, -1)]
        ent_sum = jnp.zeros((b,), jnp.float32)
        for _ in range(args.gen_len - 1):
            logits, cache = step(params, toks[-1], cache)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            ent_sum += -jnp.sum(jnp.exp(logp) * logp, -1)
            toks.append(jnp.argmax(logits, -1))
        gen = jnp.stack(toks, 1)  # (b, gen_len)
        scores = np.asarray(ent_sum / (args.gen_len - 1))
        ids = np.arange(served, served + b)
        if engine is not None:
            # interleave requests across tenants; doc index is per-tenant
            engine.ingest(ids % args.tenants, scores, ids // args.tenants)
        else:
            payloads = np.concatenate([prompts, np.asarray(gen)], axis=1)
            curator.observe_batch(ids, scores, payloads)
        served += b
        if args.obs_hold > 0:
            time.sleep(args.obs_hold / n_batches)
    dt = time.time() - t0

    if stop["signal"] is not None:
        print(f"graceful shutdown on {signal.Signals(stop['signal']).name}: "
              f"served {served}/{args.requests} requests", flush=True)
    print(f"served {served} requests in {dt:.1f}s "
          f"({served * (args.prompt_len + args.gen_len) / dt:.0f} tok/s)")
    if checkpointer is not None:
        gen = checkpointer.save(engine, blocking=True)
        print(f"final checkpoint: generation {gen} at chunk "
              f"{engine.chunks_ingested} -> {args.ckpt_dir}", flush=True)
    if engine is not None:
        survivors = engine.finalize()
        rec = engine.meter.reconcile(batch=max(1, args.batch // args.tenants))
        print(f"fleet ledger: writes actual={rec['fleet_actual']:.0f} "
              f"expected={rec['fleet_expected']:.1f} "
              f"mean rel err={rec['mean_rel_err']:+.2%}")
        hist = engine.plan.strategy_histogram()
        print("per-stream strategies: "
              + ", ".join(f"{s}={c}" for s, c in sorted(hist.items())))
        if obs is not None and obs.config.costs:
            summ = engine.cost_summary()
            print(f"cost attribution: realized={summ['total'].sum():.3e} "
                  f"planned={summ['planned'].sum():.3e} "
                  f"regret={summ['regret'].sum():+.3e}")
        for t in sorted(survivors)[:4]:
            reqs = (np.asarray(survivors[t]) * args.tenants + t).tolist()
            print(f"tenant {t}: top-{tenant_specs[t].k} retained requests "
                  f"{reqs}")
        if args.tenants > 4:
            print(f"... ({args.tenants - 4} more tenants)")
    else:
        print(f"curation: {curator.stats.as_dict()}")
        print(f"ledger: {store.ledger.as_dict()}")
        retained = curator.finalize()
        print(f"top-{args.topk} most-uncertain requests retained for review: "
              f"{sorted(retained)}")
    if obs is not None and args.obs_out is not None:
        paths = obs.write(args.obs_out)
        snap = obs.snapshot()
        jit = snap.get("jit", {})
        print("obs: " + ", ".join(
            f"{name} calls={p['calls']} misses={p['misses']}"
            for name, p in sorted(jit.items())) if jit else
            "obs: no jit probes fired")
        print("obs artifacts: " + ", ".join(sorted(paths.values())))
    if obs_server is not None:
        obs_server.stop()


if __name__ == "__main__":
    main()
