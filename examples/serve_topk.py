"""Serving example: batched prefill+decode with top-K request logging.

A small LM serves batches of requests; every completed request is scored by
predictive entropy (uncertainty), and the top-K most "interesting" requests
per window are retained in tiered storage (hot ring buffer → cold store) at
the placement the SHP plan chose — exactly the paper's workflow with the
serving fleet as the producer and offline analysis as the consumer.

Run: PYTHONPATH=src python examples/serve_topk.py [--requests 64]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import costs, placement, shp, tiers
from repro.data.curation import TopKCurator
from repro.models import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=12)
    ap.add_argument("--topk", type=int, default=8)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, reduced=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    print(f"serving reduced {args.arch}: vocab={cfg.vocab_size}")

    # proactive placement for the request-log stream
    cm = costs.hbm_host_preset(n_docs=args.requests, k=args.topk,
                               doc_gb=(args.prompt_len + args.gen_len) * 4 / 1e9,
                               window_seconds=60.0)
    plan = shp.plan_placement(cm)
    pol = placement.from_plan(plan)
    print(f"SHP plan for request log: {plan.strategy} "
          f"r*/N={plan.best.r_over_n:.3f}")
    store = tiers.TieredStore(
        pol, tiers.HotTier(args.topk, (args.prompt_len + args.gen_len,),
                           dtype=jnp.int32), tiers.ColdTier())
    curator = TopKCurator(args.topk, store, policy=pol)

    prefill = jax.jit(lambda p, b, c: lm.prefill(p, cfg, b, c))
    step = jax.jit(lambda p, t, c: lm.decode_step(p, cfg, t, c))
    rng = np.random.default_rng(0)

    served = 0
    t0 = time.time()
    while served < args.requests:
        b = min(args.batch, args.requests - served)
        prompts = rng.integers(0, cfg.vocab_size, (b, args.prompt_len))
        cache = lm.init_cache(cfg, b, args.prompt_len + args.gen_len + 1)
        logits, cache = prefill(params,
                                {"tokens": jnp.asarray(prompts, jnp.int32)},
                                cache)
        toks = [jnp.argmax(logits, -1)]
        ent_sum = jnp.zeros((b,), jnp.float32)
        for _ in range(args.gen_len - 1):
            logits, cache = step(params, toks[-1], cache)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            ent_sum += -jnp.sum(jnp.exp(logp) * logp, -1)
            toks.append(jnp.argmax(logits, -1))
        gen = jnp.stack(toks, 1)  # (b, gen_len)
        scores = np.asarray(ent_sum / (args.gen_len - 1))
        ids = np.arange(served, served + b)
        payloads = np.concatenate([prompts, np.asarray(gen)], axis=1)
        curator.observe_batch(ids, scores, payloads)
        served += b
    dt = time.time() - t0

    print(f"served {served} requests in {dt:.1f}s "
          f"({served * (args.prompt_len + args.gen_len) / dt:.0f} tok/s)")
    print(f"curation: {curator.stats.as_dict()}")
    print(f"ledger: {store.ledger.as_dict()}")
    retained = curator.finalize()
    print(f"top-{args.topk} most-uncertain requests retained for review: "
          f"{sorted(retained)}")


if __name__ == "__main__":
    main()
