"""§V–§VI — classic SHP (Algorithm A) and simple-overwrite (Algorithm B)
expected-writes laws (eqs. 2–8), analytic vs Monte-Carlo."""
from __future__ import annotations

import math
import time

import numpy as np

from repro.core import shp


def run(emit):
    # Algorithm A: classic secretary constants
    t0 = time.perf_counter_ns()
    r = shp.classic_r_optimal(int(1e6))
    us = (time.perf_counter_ns() - t0) / 1000.0
    emit("algoA.r_opt", us, f"{r:.1f} = N/e")
    emit("algoA.p_best", us, f"{shp.classic_p_best():.4f} (paper 0.367)")
    emit("algoA.expected_writes", us, f"{shp.classic_expected_writes():.0f}")

    # Algorithm B: E[#writes] = H_N ≈ ln N + 0.57722 (eqs. 6–7)
    n = 100_000
    t0 = time.perf_counter_ns()
    exact = float(shp.expected_cum_writes(n - 1, 1))
    us = (time.perf_counter_ns() - t0) / 1000.0
    emit("algoB.expected_writes_H_N", us,
         f"{exact:.4f} (lnN+gamma={math.log(n)+0.57722:.4f})")

    # Monte-Carlo check of the K>1 law
    rng = np.random.default_rng(0)
    n, k, trials = 5000, 25, 40
    t0 = time.perf_counter_ns()
    mc = []
    for _ in range(trials):
        ranks = rng.permutation(n)
        # doc i writes iff rank among first i+1 is in top-k
        best = []
        writes = 0
        import heapq
        for i in range(n):
            if len(best) < k:
                heapq.heappush(best, ranks[i])
                writes += 1
            elif ranks[i] > best[0]:
                heapq.heapreplace(best, ranks[i])
                writes += 1
        mc.append(writes)
    us = (time.perf_counter_ns() - t0) / 1000.0
    analytic = float(shp.expected_cum_writes(n - 1, k))
    emit("algoB.k25_monte_carlo", us,
         f"{np.mean(mc):.1f} (analytic {analytic:.1f})")
    assert abs(np.mean(mc) - analytic) / analytic < 0.03

    # batched-stream generalization (beyond paper, DESIGN §3)
    t0 = time.perf_counter_ns()
    batched = float(shp.expected_cum_writes_batched(n - 1, k, 32))
    us = (time.perf_counter_ns() - t0) / 1000.0
    emit("algoB.k25_batched32", us,
         f"{batched:.1f} (fewer than per-element {analytic:.1f})")
    assert batched < analytic
