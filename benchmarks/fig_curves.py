"""Figures 4 & 5 — expected total cost vs r for both case studies.
Writes CSV curves to artifacts/ and asserts the analytic r* is the argmin."""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import costs, shp

OUT = os.path.join(os.path.dirname(__file__), "..", "artifacts", "curves")


def _curve(name, cm, migrate, r_star, emit):
    t0 = time.perf_counter_ns()
    curve = shp.cost_curve(cm, migrate=migrate, num=1024)
    us = (time.perf_counter_ns() - t0) / 1000.0
    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, f"{name}.csv")
    np.savetxt(path, curve, delimiter=",", header="r_over_n,expected_cost",
               comments="")
    i = int(np.argmin(curve[:, 1]))
    emit(f"{name}.min_at_r_over_n", us,
         f"{curve[i,0]:.4f} (analytic {r_star/cm.workload.n_docs:.4f})")
    emit(f"{name}.min_cost", us, f"${curve[i,1]:.2f}")
    assert abs(curve[i, 0] - r_star / cm.workload.n_docs) < 2e-3


def run(emit):
    cm1 = costs.case_study_1()
    _curve("fig4_case1_no_migration", cm1, False,
           shp.r_optimal_no_migration(cm1), emit)
    cm2 = costs.case_study_2()
    _curve("fig5_case2_migration", cm2, True,
           shp.r_optimal_migration(cm2), emit)
