"""Fleet-engine throughput: docs/sec of one jitted multi-stream step vs M.

Times the device-side batched update (the jitted sort-merge over all
streams) and the kernel-filtered path's algorithmic reference (the Pallas
body itself runs in interpret mode off-TPU, so it is timed only at a token
size for correctness, like kernels_bench). Standalone entry point emits
``BENCH_streams.json``; also wired into ``benchmarks/run.py``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.streams import engine

K, BATCH = 16, 64
SWEEP_M = (64, 256, 1024)


def _time(fn, *args, reps=20):
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter_ns()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter_ns() - t0) / 1000.0 / reps


def run(emit):
    rng = np.random.default_rng(0)
    upd = jax.jit(engine.update)
    filt = jax.jit(lambda st, s, i: engine.filtered_update(
        st, s, i, use_pallas=False))
    for m in SWEEP_M:
        state = engine.init(m, K)
        sc = jnp.asarray(rng.standard_normal((m, BATCH)), jnp.float32)
        ids = jnp.tile(jnp.arange(BATCH, dtype=jnp.int32), (m, 1))
        us = _time(upd, state, sc, ids)
        emit(f"streams.update_m{m}_k{K}_b{BATCH}", us,
             f"{m * BATCH / us * 1e6:.0f} docs/s fused sort-merge")
        us = _time(filt, state, sc, ids)
        emit(f"streams.filtered_update_m{m}_k{K}_b{BATCH}", us,
             f"{m * BATCH / us * 1e6:.0f} docs/s filter+merge (jnp ref)")
    # Pallas body correctness-scale timing (interpret mode off-TPU)
    state = engine.init(8, K)
    sc = jnp.asarray(rng.standard_normal((8, 256)), jnp.float32)
    ids = jnp.tile(jnp.arange(256, dtype=jnp.int32), (8, 1))
    pal = jax.jit(lambda st, s, i: engine.filtered_update(st, s, i,
                                                          block_n=128))
    us = _time(pal, state, sc, ids, reps=3)
    emit(f"streams.filtered_update_pallas_interpret_m8_b256", us,
         "Pallas 2-D grid (interpret mode, correctness only)")


def main():
    try:
        from benchmarks.run import write_trajectory
    except ImportError:  # bare-script invocation: benchmarks/ is sys.path[0]
        from run import write_trajectory
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_streams.json",
                    help="output trajectory file")
    args = ap.parse_args()
    rows = []

    def emit(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}")
        rows.append({"name": name, "us_per_call": us, "derived": derived})

    run(emit)
    print(f"wrote {write_trajectory('streams', rows, args.json)}")


if __name__ == "__main__":
    main()
