"""Fleet-engine throughput: docs/sec of one jitted multi-stream step vs M.

Times the device-side batched update (the jitted sort-merge over all
streams), the kernel-filtered path, and the online drift detector
(``repro.online.drift.update`` — the (M,)-batched sequential statistics
that ride inside the engine step). The Pallas-backed filtered path is
*compiled* when a real TPU backend is present and timed across the full
sweep; on CPU/GPU it falls back to interpret mode at a token size
(correctness only) and the row label says so — the perf trajectory then
carries compiled numbers only where they mean something. Standalone entry
point writes ``BENCH_streams.json`` under ``--out-dir`` (default
``bench_out/``; the committed repo-root copy is the canonical snapshot);
also wired into ``benchmarks/run.py``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import Observability, ObsConfig, timers
from repro.online import drift
from repro.streams import engine

K, BATCH = 16, 64
SWEEP_M = (64, 256, 1024)
DRIFT_M = (1024, 16384)
# fleet-mesh scaling rows: (M, W) pairs; emitted only when jax sees a
# multi-device mesh (CI forces 8 CPU devices via
# XLA_FLAGS=--xla_force_host_platform_device_count=8)
SHARD_SWEEP = ((65_536, 64), (1_000_000, 16))

_time = timers.time_jax  # the shared device-dispatch discipline


def _engine_step_pair(emit, m, rng):
    """The full fleet-engine jitted step, telemetry off vs on: the pair of
    headline rows the obs layer's <3%-overhead budget is checked against
    (same routed batch, same bucket structure; the obs variant carries the
    device ``MetricsState`` accumulators through the step)."""
    specs = [engine.StreamSpec(stream_id=i, k=K, r=4096.0)
             for i in range(m)]
    sids = np.repeat(np.arange(m), BATCH)
    dids = np.tile(np.arange(BATCH), m)
    sc = rng.standard_normal(m * BATCH)
    variants = []
    for suffix, obs in (("", None),
                        ("_obs", Observability(ObsConfig(residuals=False)))):
        eng = engine.StreamEngine(specs, obs=obs)
        routed = eng.router.route(sids, sc, dids)
        batches = tuple((jnp.asarray(s), jnp.asarray(i)) for s, i in routed)
        mstate = (eng._metrics_state
                  if eng._metrics_state is not None else ())
        variants.append((suffix, obs, eng, batches, mstate,
                         [float("inf")]))
    # interleaved min-of-rounds: the pair's delta is the obs overhead
    # budget, so both variants must sample the same machine weather —
    # alternating rounds and keeping the min is robust to the contention
    # spikes a single long rep window averages in
    for _ in range(32):
        for _, _, eng, batches, mstate, best in variants:
            best[0] = min(best[0],
                          _time(eng._step, tuple(eng._states), batches,
                                (), mstate, reps=25))
    for suffix, obs, _, _, _, best in variants:
        us = best[0]
        emit(f"streams.engine_step{suffix}_m{m}_k{K}_b{BATCH}", us,
             f"{m * BATCH / us * 1e6:.0f} docs/s fleet step "
             f"({'device metrics on' if obs else 'telemetry off'})")


def _sharded_step_rows(emit, rng):
    """Fleet-axis scaling: the same jitted engine step, single-device vs
    shard_map-ped over the mesh, on identical inputs — emitted as a
    same-run pair (``.ref1`` / ``.sharded_dN``) so ``run.py --check``
    can guard the speedup without cross-machine assumptions. Throughput
    only; bit-identity is asserted in tests/test_sharded.py."""
    from repro.parallel import fleet
    mesh = fleet.fleet_mesh(min(jax.local_device_count(), 8))
    if mesh is None:
        return
    shards = fleet.n_shards(mesh)
    for m, w in SHARD_SWEEP:
        reps, rounds = (10, 8) if m <= 100_000 else (2, 2)
        step1 = engine._make_step(False, 512, update_path="auto")
        stepd = engine._make_step(False, 512, update_path="auto",
                                  mesh=mesh)
        sc = rng.standard_normal((m, w)).astype(np.float32)
        ids = np.tile(np.arange(w, dtype=np.int32), (m, 1))
        st = engine.init(m, K)
        sh = fleet.row_sharding(mesh)
        variants = [
            ("ref1", step1, ((st,), ((jnp.asarray(sc),
                                      jnp.asarray(ids)),), (), ())),
            (f"sharded_d{shards}", stepd,
             (((fleet.shard_rows(mesh, st)),),
              ((jax.device_put(sc, sh), jax.device_put(ids, sh)),),
              (), ())),
        ]
        best = {name: float("inf") for name, _, _ in variants}
        for _ in range(rounds):  # interleaved: same machine weather
            for name, step, args in variants:
                best[name] = min(best[name], _time(step, *args, reps=reps))
        us1 = best["ref1"]
        emit(f"streams.engine_step_m{m}_k{K}_b{w}.ref1", us1,
             f"{m * w / us1 * 1e6:.0f} docs/s single-device reference")
        usd = best[f"sharded_d{shards}"]
        emit(f"streams.engine_step_m{m}_k{K}_b{w}.sharded_d{shards}", usd,
             f"{m * w / usd * 1e6:.0f} docs/s on {shards} shards "
             f"({us1 / usd:.2f}x vs same-run 1-device ref)")


def run(emit):
    rng = np.random.default_rng(0)
    on_tpu = jax.default_backend() == "tpu"
    upd = jax.jit(engine.update)
    filt = jax.jit(lambda st, s, i: engine.filtered_update(
        st, s, i, use_pallas=False))
    pal = jax.jit(lambda st, s, i: engine.filtered_update(st, s, i))
    for m in SWEEP_M:
        state = engine.init(m, K)
        sc = jnp.asarray(rng.standard_normal((m, BATCH)), jnp.float32)
        ids = jnp.tile(jnp.arange(BATCH, dtype=jnp.int32), (m, 1))
        # headline row first: the jnp filter+merge is what StreamEngine
        # ships on wide batches (update_path="auto") — it beat the fused
        # sort-merge at every M, so the engine now dispatches to it
        us = _time(filt, state, sc, ids)
        emit(f"streams.filtered_update_m{m}_k{K}_b{BATCH}", us,
             f"{m * BATCH / us * 1e6:.0f} docs/s filter+merge "
             f"(engine default path)")
        us = _time(upd, state, sc, ids)
        emit(f"streams.update_m{m}_k{K}_b{BATCH}", us,
             f"{m * BATCH / us * 1e6:.0f} docs/s vmap sort-merge "
             f"(legacy fused path; narrow batches only)")
        if on_tpu:
            us = _time(pal, state, sc, ids)
            emit(f"streams.filtered_update_pallas_m{m}_k{K}_b{BATCH}", us,
                 f"{m * BATCH / us * 1e6:.0f} docs/s Pallas 2-D grid "
                 f"(compiled, tpu)")
        _engine_step_pair(emit, m, rng)
    if not on_tpu:
        # interpret-mode fallback at a token size: correctness only, kept
        # out of the compiled perf trajectory by the explicit label
        state = engine.init(8, K)
        sc = jnp.asarray(rng.standard_normal((8, 256)), jnp.float32)
        ids = jnp.tile(jnp.arange(256, dtype=jnp.int32), (8, 1))
        small = jax.jit(lambda st, s, i: engine.filtered_update(
            st, s, i, block_n=128))
        us = _time(small, state, sc, ids, reps=3)
        emit("streams.filtered_update_pallas_interpret_m8_b256", us,
             f"Pallas 2-D grid (interpret fallback, "
             f"{jax.default_backend()}; correctness only)")
    # online drift detector: the (M,)-batched per-chunk update
    cfg = drift.DriftConfig()
    for m in DRIFT_M:
        kf = jnp.full((m,), float(K), jnp.float32)
        step = jax.jit(lambda st, w, s: drift.update(st, w, s, kf, cfg))
        # one BATCH-doc chunk per stream: prefix 512-BATCH -> 512
        st = drift.init(m)._replace(
            seen=jnp.full((m,), float(512 - BATCH), jnp.float32))
        w = jnp.asarray(rng.poisson(2.0, m), jnp.float32)
        seen = jnp.full((m,), 512.0, jnp.float32)
        us = _time(step, st, w, seen)
        emit(f"online.drift_update_m{m}", us,
             f"{m * BATCH / us * 1e6:.0f} docs/s detector "
             f"(M-batched {BATCH}-doc chunk stats)")
    _sharded_step_rows(emit, rng)


def main():
    try:
        from benchmarks.run import write_trajectory
    except ImportError:  # bare-script invocation: benchmarks/ is sys.path[0]
        from run import write_trajectory
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="explicit output path (overrides --out-dir)")
    ap.add_argument("--out-dir", default="bench_out",
                    help="directory for BENCH_streams.json")
    args = ap.parse_args()
    rows = []

    def emit(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}")
        rows.append({"name": name, "us_per_call": us, "derived": derived,
                     "ts": time.time()})

    run(emit)
    print(f"wrote {write_trajectory('streams', rows, args.json, args.out_dir)}")


if __name__ == "__main__":
    main()
