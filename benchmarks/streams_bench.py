"""Fleet-engine throughput: docs/sec of one jitted multi-stream step vs M.

Times the device-side batched update (the jitted sort-merge over all
streams), the kernel-filtered path, and the online drift detector
(``repro.online.drift.update`` — the (M,)-batched sequential statistics
that ride inside the engine step). The Pallas-backed filtered path is
*compiled* when a real TPU backend is present and timed across the full
sweep; on CPU/GPU it falls back to interpret mode at a token size
(correctness only) and the row label says so — the perf trajectory then
carries compiled numbers only where they mean something. Standalone entry
point writes ``BENCH_streams.json`` under ``--out-dir`` (default
``bench_out/``; the committed repo-root copy is the canonical snapshot);
also wired into ``benchmarks/run.py``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import Observability, ObsConfig, timers
from repro.online import drift
from repro.streams import engine

K, BATCH = 16, 64
SWEEP_M = (64, 256, 1024)
DRIFT_M = (1024, 16384)

_time = timers.time_jax  # the shared device-dispatch discipline


def _engine_step_pair(emit, m, rng):
    """The full fleet-engine jitted step, telemetry off vs on: the pair of
    headline rows the obs layer's <3%-overhead budget is checked against
    (same routed batch, same bucket structure; the obs variant carries the
    device ``MetricsState`` accumulators through the step)."""
    specs = [engine.StreamSpec(stream_id=i, k=K, r=4096.0)
             for i in range(m)]
    sids = np.repeat(np.arange(m), BATCH)
    dids = np.tile(np.arange(BATCH), m)
    sc = rng.standard_normal(m * BATCH)
    variants = []
    for suffix, obs in (("", None),
                        ("_obs", Observability(ObsConfig(residuals=False)))):
        eng = engine.StreamEngine(specs, obs=obs)
        routed = eng.router.route(sids, sc, dids)
        batches = tuple((jnp.asarray(s), jnp.asarray(i)) for s, i in routed)
        mstate = (eng._metrics_state
                  if eng._metrics_state is not None else ())
        variants.append((suffix, obs, eng, batches, mstate,
                         [float("inf")]))
    # interleaved min-of-rounds: the pair's delta is the obs overhead
    # budget, so both variants must sample the same machine weather —
    # alternating rounds and keeping the min is robust to the contention
    # spikes a single long rep window averages in
    for _ in range(32):
        for _, _, eng, batches, mstate, best in variants:
            best[0] = min(best[0],
                          _time(eng._step, tuple(eng._states), batches,
                                (), mstate, reps=25))
    for suffix, obs, _, _, _, best in variants:
        us = best[0]
        emit(f"streams.engine_step{suffix}_m{m}_k{K}_b{BATCH}", us,
             f"{m * BATCH / us * 1e6:.0f} docs/s fleet step "
             f"({'device metrics on' if obs else 'telemetry off'})")


def run(emit):
    rng = np.random.default_rng(0)
    on_tpu = jax.default_backend() == "tpu"
    upd = jax.jit(engine.update)
    filt = jax.jit(lambda st, s, i: engine.filtered_update(
        st, s, i, use_pallas=False))
    pal = jax.jit(lambda st, s, i: engine.filtered_update(st, s, i))
    for m in SWEEP_M:
        state = engine.init(m, K)
        sc = jnp.asarray(rng.standard_normal((m, BATCH)), jnp.float32)
        ids = jnp.tile(jnp.arange(BATCH, dtype=jnp.int32), (m, 1))
        # headline row first: the jnp filter+merge is what StreamEngine
        # ships on wide batches (update_path="auto") — it beat the fused
        # sort-merge at every M, so the engine now dispatches to it
        us = _time(filt, state, sc, ids)
        emit(f"streams.filtered_update_m{m}_k{K}_b{BATCH}", us,
             f"{m * BATCH / us * 1e6:.0f} docs/s filter+merge "
             f"(engine default path)")
        us = _time(upd, state, sc, ids)
        emit(f"streams.update_m{m}_k{K}_b{BATCH}", us,
             f"{m * BATCH / us * 1e6:.0f} docs/s vmap sort-merge "
             f"(legacy fused path; narrow batches only)")
        if on_tpu:
            us = _time(pal, state, sc, ids)
            emit(f"streams.filtered_update_pallas_m{m}_k{K}_b{BATCH}", us,
                 f"{m * BATCH / us * 1e6:.0f} docs/s Pallas 2-D grid "
                 f"(compiled, tpu)")
        _engine_step_pair(emit, m, rng)
    if not on_tpu:
        # interpret-mode fallback at a token size: correctness only, kept
        # out of the compiled perf trajectory by the explicit label
        state = engine.init(8, K)
        sc = jnp.asarray(rng.standard_normal((8, 256)), jnp.float32)
        ids = jnp.tile(jnp.arange(256, dtype=jnp.int32), (8, 1))
        small = jax.jit(lambda st, s, i: engine.filtered_update(
            st, s, i, block_n=128))
        us = _time(small, state, sc, ids, reps=3)
        emit("streams.filtered_update_pallas_interpret_m8_b256", us,
             f"Pallas 2-D grid (interpret fallback, "
             f"{jax.default_backend()}; correctness only)")
    # online drift detector: the (M,)-batched per-chunk update
    cfg = drift.DriftConfig()
    for m in DRIFT_M:
        kf = jnp.full((m,), float(K), jnp.float32)
        step = jax.jit(lambda st, w, s: drift.update(st, w, s, kf, cfg))
        # one BATCH-doc chunk per stream: prefix 512-BATCH -> 512
        st = drift.init(m)._replace(
            seen=jnp.full((m,), float(512 - BATCH), jnp.float32))
        w = jnp.asarray(rng.poisson(2.0, m), jnp.float32)
        seen = jnp.full((m,), 512.0, jnp.float32)
        us = _time(step, st, w, seen)
        emit(f"online.drift_update_m{m}", us,
             f"{m * BATCH / us * 1e6:.0f} docs/s detector "
             f"(M-batched {BATCH}-doc chunk stats)")


def main():
    try:
        from benchmarks.run import write_trajectory
    except ImportError:  # bare-script invocation: benchmarks/ is sys.path[0]
        from run import write_trajectory
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="explicit output path (overrides --out-dir)")
    ap.add_argument("--out-dir", default="bench_out",
                    help="directory for BENCH_streams.json")
    args = ap.parse_args()
    rows = []

    def emit(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}")
        rows.append({"name": name, "us_per_call": us, "derived": derived,
                     "ts": time.time()})

    run(emit)
    print(f"wrote {write_trajectory('streams', rows, args.json, args.out_dir)}")


if __name__ == "__main__":
    main()
