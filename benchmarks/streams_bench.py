"""Fleet-engine throughput: docs/sec of one jitted multi-stream step vs M.

Times the device-side batched update (the jitted sort-merge over all
streams), the kernel-filtered path, and the online drift detector
(``repro.online.drift.update`` — the (M,)-batched sequential statistics
that ride inside the engine step). The Pallas-backed filtered path is
*compiled* when a real TPU backend is present and timed across the full
sweep; on CPU/GPU it falls back to interpret mode at a token size
(correctness only) and the row label says so — the perf trajectory then
carries compiled numbers only where they mean something. Standalone entry
point writes ``BENCH_streams.json`` under ``--out-dir`` (default
``bench_out/``; the committed repo-root copy is the canonical snapshot);
also wired into ``benchmarks/run.py``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import Observability, ObsConfig, timers
from repro.online import drift
from repro.streams import engine

K, BATCH = 16, 64
SWEEP_M = (64, 256, 1024)
DRIFT_M = (1024, 16384)
# engine-backend pairs: matched (K, M, W) fleets, exact vs logmem — the
# rows carry bytes_per_stream extras that run.py --check holds to the
# memory-regression floor (logmem >= 8x leaner at K >= 4096)
# reps/rounds shrink with K: the exact step's narrow-batch path pays an
# O(W*K) resident-id dedupe per call (seconds at K=65536 on CPU), and
# the floor guards deterministic bytes, not the timing
BACKEND_SWEEP = ((256, 256, 512, 5, 4), (4_096, 128, 1_024, 3, 2),
                 (65_536, 8, 1_024, 1, 2))  # (K, M, W, reps, rounds)
# competitive-ratio harness traces: (K, M, n, chunk)
RATIO_SWEEP = ((256, 64, 16_384, 512), (4_096, 8, 131_072, 2_048),
               (65_536, 2, 262_144, 8_192))
# fleet-mesh scaling rows: (M, W) pairs; emitted only when jax sees a
# multi-device mesh (CI forces 8 CPU devices via
# XLA_FLAGS=--xla_force_host_platform_device_count=8)
SHARD_SWEEP = ((65_536, 64), (1_000_000, 16))

_time = timers.time_jax  # the shared device-dispatch discipline


def _engine_step_pair(emit, m, rng):
    """The full fleet-engine jitted step, telemetry off vs on vs on-with-
    costs: the row triple the obs layer's overhead budgets are checked
    against (same routed batch, same bucket structure; the obs variant
    carries the device ``MetricsState`` accumulators through the step,
    the costobs variant additionally folds the per-(stream, tier)
    ``CostState`` ledger — run.py --check holds costobs within 5% of
    obs, same-run)."""
    specs = [engine.StreamSpec(stream_id=i, k=K, r=4096.0)
             for i in range(m)]
    sids = np.repeat(np.arange(m), BATCH)
    dids = np.tile(np.arange(BATCH), m)
    sc = rng.standard_normal(m * BATCH)
    labels = {"": "telemetry off", "_obs": "device metrics on",
              "_costobs": "metrics + cost ledger on"}
    variants = []
    for suffix, obs in (
            ("", None),
            ("_obs", Observability(ObsConfig(residuals=False))),
            ("_costobs", Observability(ObsConfig(residuals=False,
                                                 costs=True)))):
        eng = engine.StreamEngine(specs, obs=obs)
        routed = eng.router.route(sids, sc, dids)
        batches = tuple((jnp.asarray(s), jnp.asarray(i)) for s, i in routed)
        mstate = (eng._metrics_state
                  if eng._metrics_state is not None else ())
        cstates = (tuple(eng._cost_states)
                   if eng._cost_states is not None else ())
        variants.append((suffix, eng, batches, mstate, cstates,
                         [float("inf")]))
    # interleaved min-of-rounds: the deltas inside the triple are the obs
    # overhead budgets, so all variants must sample the same machine
    # weather — alternating rounds and keeping the min is robust to the
    # contention spikes a single long rep window averages in
    for _ in range(32):
        for _, eng, batches, mstate, cstates, best in variants:
            best[0] = min(best[0],
                          _time(eng._step, tuple(eng._states), batches,
                                (), mstate, cstates, reps=25))
    for suffix, _, _, _, _, best in variants:
        us = best[0]
        emit(f"streams.engine_step{suffix}_m{m}_k{K}_b{BATCH}", us,
             f"{m * BATCH / us * 1e6:.0f} docs/s fleet step "
             f"({labels[suffix]})")


def _state_bytes_per_stream(states) -> float:
    """Device bytes per stream across a fleet's bucket states (pytree
    leaves / total rows) — the number the memory floor guards."""
    total = sum(int(np.prod(leaf.shape)) * leaf.dtype.itemsize
                for st in states for leaf in st)
    rows = sum(int(st[0].shape[0]) for st in states)
    return total / max(rows, 1)


def _backend_rows(emit, rng):
    """Paired exact/logmem engine-step rows at matched (K, M, W): same
    batch, same bucket structure, interleaved min-of-rounds so the
    pair's delta is the backend, not machine weather. Each row carries
    ``bytes_per_stream`` + ``k`` extras; ``run.py --check`` pairs the
    ``.exact``/``.logmem`` suffixes same-run and fails when logmem's
    memory advantage drops under the floor."""
    for k, m, w, reps, rounds in BACKEND_SWEEP:
        sc = rng.standard_normal((m, w)).astype(np.float32)
        ids = np.tile(np.arange(w, dtype=np.int32), (m, 1))
        batches = ((jnp.asarray(sc), jnp.asarray(ids)),)
        variants = []
        for backend in ("exact", "logmem"):
            specs = [engine.StreamSpec(stream_id=i, k=k, r=float(4 * k),
                                       engine=backend) for i in range(m)]
            eng = engine.StreamEngine(specs)
            variants.append((backend, eng, [float("inf")]))
        for _ in range(rounds):
            for _, eng, best in variants:
                best[0] = min(best[0],
                              _time(eng._step, tuple(eng._states), batches,
                                    (), (), (), reps=reps))
        for backend, eng, best in variants:
            us = best[0]
            bps = _state_bytes_per_stream(eng._states)
            emit(f"streams.engine_backend_k{k}_m{m}_w{w}.{backend}", us,
                 f"{m * w / us * 1e6:.0f} docs/s {backend} step, "
                 f"{bps:.0f} B/stream device state",
                 bytes_per_stream=bps, k=k)


def _logmem_ratio_rows(emit, rng):
    """Simulator-trace harness rows: replay i.u.d. traces through the
    logmem backend and report the realized competitive ratio (top-K mass
    retained vs the true top-K) and its 1 − c/√K constant, plus the
    admit count against the closed-form write law."""
    from repro.streams import logmem
    for k, m, n, chunk in RATIO_SWEEP:
        sc = rng.standard_normal((m, n)).astype(np.float32)
        t0 = time.perf_counter()
        rep = logmem.trace_competitive_ratio(sc, k, chunk)
        us = (time.perf_counter() - t0) * 1e6
        emit(f"streams.logmem_ratio_k{k}_n{n}_c{chunk}", us,
             f"ratio>={rep['min_ratio']:.5f} (c<={rep['max_c']:.3f}), "
             f"admits {np.mean(rep['admit_ratio']):.3f}x law, "
             f"{rep['bytes_per_stream']:.0f} vs "
             f"{rep['exact_bytes_per_stream']:.0f} B/stream",
             min_ratio=rep["min_ratio"], max_c=rep["max_c"],
             admit_ratio=float(np.mean(rep["admit_ratio"])), k=k)


# checkpoint-overhead pair: (M, W, save cadence, chunks-per-round,
# rounds) — the README's cadence guidance regime: wide chunks (the
# fleet-scale ingest shape) and a save every 8 chunks, so the async npy
# write hides behind ~8 chunks of compute and the residual per-chunk
# cost is the synchronous host snapshot plus the final drain's tail,
# amortized over the round
CKPT_SWEEP = ((256, 1024, 8, 16, 5),)


def _ckpt_rows(emit, rng):
    """Chunk-boundary checkpointing overhead: the same double-buffered
    ``ingest_chunks`` loop with a ``FleetCheckpointer`` saving every
    chunk (async npy writes on the manager's worker thread) vs an
    identical no-checkpoint twin. Emitted as a same-run pair
    (``engine_step_ckpt_*`` / ``engine_step_ckptoff_*``, interleaved
    rounds, min-of-rounds) so ``run.py --check`` holds the snapshot +
    handoff cost within its ceiling without cross-machine assumptions.
    The timed region includes the final ``wait()`` — the tail I/O is
    part of the overhead, not free."""
    import shutil
    import tempfile

    from repro.resilience import FleetCheckpointer
    for m, w, every, n_chunks, rounds in CKPT_SWEEP:
        sc = rng.standard_normal((m, w)).astype(np.float32)
        ids = np.tile(np.arange(w, dtype=np.int32), (m, 1))
        chunk = [(sc, ids)]
        specs = [engine.StreamSpec(stream_id=i, k=K, r=float(4 * K))
                 for i in range(m)]
        tmp = tempfile.mkdtemp(prefix="bench_ckpt_")
        try:
            eng_off = engine.StreamEngine(specs)
            eng_on = engine.StreamEngine(specs)
            ck = FleetCheckpointer(tmp, every=every, keep_latest=2)
            eng_on.attach_checkpointer(ck)
            for eng in (eng_off, eng_on):  # warm the jitted step
                eng.ingest_dense(chunk)
            ck.save(eng_on, blocking=True)  # warm the save path too
            ck.wait()
            variants = [("_ckptoff", eng_off, None),
                        ("_ckpt", eng_on, ck)]
            best = {name: float("inf") for name, _, _ in variants}
            for _ in range(rounds):
                for name, eng, cw in variants:
                    t0 = time.perf_counter()
                    eng.ingest_chunks(chunk for _ in range(n_chunks))
                    if cw is not None:
                        cw.wait()
                    us = (time.perf_counter() - t0) * 1e6 / n_chunks
                    best[name] = min(best[name], us)
            for name, _, _ in variants:
                us = best[name]
                what = (f"per-chunk ingest + async checkpoint "
                        f"(every {every} chunks)" if name == "_ckpt"
                        else "per-chunk ingest, checkpointing off")
                emit(f"streams.engine_step{name}_m{m}_k{K}_w{w}", us,
                     f"{m * w / us * 1e6:.0f} docs/s {what}")
        finally:
            shutil.rmtree(tmp, ignore_errors=True)


def _sharded_step_rows(emit, rng):
    """Fleet-axis scaling: the same jitted engine step, single-device vs
    shard_map-ped over the mesh, on identical inputs — emitted as a
    same-run pair (``.ref1`` / ``.sharded_dN``) so ``run.py --check``
    can guard the speedup without cross-machine assumptions. Throughput
    only; bit-identity is asserted in tests/test_sharded.py."""
    from repro.parallel import fleet
    mesh = fleet.fleet_mesh(min(jax.local_device_count(), 8))
    if mesh is None:
        return
    shards = fleet.n_shards(mesh)
    for m, w in SHARD_SWEEP:
        reps, rounds = (10, 8) if m <= 100_000 else (2, 2)
        step1 = engine._make_step(False, 512, update_path="auto")
        stepd = engine._make_step(False, 512, update_path="auto",
                                  mesh=mesh)
        sc = rng.standard_normal((m, w)).astype(np.float32)
        ids = np.tile(np.arange(w, dtype=np.int32), (m, 1))
        st = engine.init(m, K)
        sh = fleet.row_sharding(mesh)
        variants = [
            ("ref1", step1, ((st,), ((jnp.asarray(sc),
                                      jnp.asarray(ids)),), (), (), ())),
            (f"sharded_d{shards}", stepd,
             (((fleet.shard_rows(mesh, st)),),
              ((jax.device_put(sc, sh), jax.device_put(ids, sh)),),
              (), (), ())),
        ]
        best = {name: float("inf") for name, _, _ in variants}
        for _ in range(rounds):  # interleaved: same machine weather
            for name, step, args in variants:
                best[name] = min(best[name], _time(step, *args, reps=reps))
        us1 = best["ref1"]
        emit(f"streams.engine_step_m{m}_k{K}_b{w}.ref1", us1,
             f"{m * w / us1 * 1e6:.0f} docs/s single-device reference")
        usd = best[f"sharded_d{shards}"]
        emit(f"streams.engine_step_m{m}_k{K}_b{w}.sharded_d{shards}", usd,
             f"{m * w / usd * 1e6:.0f} docs/s on {shards} shards "
             f"({us1 / usd:.2f}x vs same-run 1-device ref)")


def run(emit):
    rng = np.random.default_rng(0)
    on_tpu = jax.default_backend() == "tpu"
    upd = jax.jit(engine.update)
    filt = jax.jit(lambda st, s, i: engine.filtered_update(
        st, s, i, use_pallas=False))
    pal = jax.jit(lambda st, s, i: engine.filtered_update(st, s, i))
    for m in SWEEP_M:
        state = engine.init(m, K)
        sc = jnp.asarray(rng.standard_normal((m, BATCH)), jnp.float32)
        ids = jnp.tile(jnp.arange(BATCH, dtype=jnp.int32), (m, 1))
        # headline row first: the jnp filter+merge is what StreamEngine
        # ships on wide batches (update_path="auto") — it beat the fused
        # sort-merge at every M, so the engine now dispatches to it
        us = _time(filt, state, sc, ids)
        emit(f"streams.filtered_update_m{m}_k{K}_b{BATCH}", us,
             f"{m * BATCH / us * 1e6:.0f} docs/s filter+merge "
             f"(engine default path)")
        us = _time(upd, state, sc, ids)
        emit(f"streams.update_m{m}_k{K}_b{BATCH}", us,
             f"{m * BATCH / us * 1e6:.0f} docs/s vmap sort-merge "
             f"(legacy fused path; narrow batches only)")
        if on_tpu:
            us = _time(pal, state, sc, ids)
            emit(f"streams.filtered_update_pallas_m{m}_k{K}_b{BATCH}", us,
                 f"{m * BATCH / us * 1e6:.0f} docs/s Pallas 2-D grid "
                 f"(compiled, tpu)")
        _engine_step_pair(emit, m, rng)
    if not on_tpu:
        # interpret-mode fallback at a token size: correctness only, kept
        # out of the compiled perf trajectory by the explicit label
        state = engine.init(8, K)
        sc = jnp.asarray(rng.standard_normal((8, 256)), jnp.float32)
        ids = jnp.tile(jnp.arange(256, dtype=jnp.int32), (8, 1))
        small = jax.jit(lambda st, s, i: engine.filtered_update(
            st, s, i, block_n=128))
        us = _time(small, state, sc, ids, reps=3)
        emit("streams.filtered_update_pallas_interpret_m8_b256", us,
             f"Pallas 2-D grid (interpret fallback, "
             f"{jax.default_backend()}; correctness only)")
    # online drift detector: the (M,)-batched per-chunk update
    cfg = drift.DriftConfig()
    for m in DRIFT_M:
        kf = jnp.full((m,), float(K), jnp.float32)
        step = jax.jit(lambda st, w, s: drift.update(st, w, s, kf, cfg))
        # one BATCH-doc chunk per stream: prefix 512-BATCH -> 512
        st = drift.init(m)._replace(
            seen=jnp.full((m,), float(512 - BATCH), jnp.float32))
        w = jnp.asarray(rng.poisson(2.0, m), jnp.float32)
        seen = jnp.full((m,), 512.0, jnp.float32)
        us = _time(step, st, w, seen)
        emit(f"online.drift_update_m{m}", us,
             f"{m * BATCH / us * 1e6:.0f} docs/s detector "
             f"(M-batched {BATCH}-doc chunk stats)")
    _backend_rows(emit, rng)
    _logmem_ratio_rows(emit, rng)
    _ckpt_rows(emit, rng)
    _sharded_step_rows(emit, rng)


def main():
    try:
        from benchmarks.run import write_trajectory
    except ImportError:  # bare-script invocation: benchmarks/ is sys.path[0]
        from run import write_trajectory
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="explicit output path (overrides --out-dir)")
    ap.add_argument("--out-dir", default="bench_out",
                    help="directory for BENCH_streams.json")
    args = ap.parse_args()
    rows = []

    def emit(name, us, derived="", **extra):
        print(f"{name},{us:.1f},{derived}")
        rows.append({"name": name, "us_per_call": us, "derived": derived,
                     **extra, "ts": time.time()})

    run(emit)
    print(f"wrote {write_trajectory('streams', rows, args.json, args.out_dir)}")


if __name__ == "__main__":
    main()
