"""Kernel microbenchmarks (CPU wall-clock of the jitted public ops; the
Pallas bodies run in interpret mode here — TPU numbers come from the
roofline analysis, not this harness)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import topk as topk_mod
from repro.kernels.entropy_scores import ops as ent_ops
from repro.kernels.topk_filter import ops as tf_ops


def _time(fn, *args, reps=20):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter_ns()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter_ns() - t0) / 1000.0 / reps


def run(emit):
    rng = np.random.default_rng(0)

    logits = jnp.asarray(rng.standard_normal((64, 32000)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 32000, 64), jnp.int32)
    us = _time(lambda l, y: ent_ops.entropy_nll(l, y, use_pallas=False),
               logits, labels)
    emit("kernel.entropy_nll.ref_64x32000", us, "pure-jnp oracle")
    us = _time(lambda l, y: ent_ops.entropy_nll(l, y), logits, labels, reps=3)
    emit("kernel.entropy_nll.pallas_interpret_64x32000", us,
         "Pallas body (interpret mode, correctness only)")

    scores = jnp.asarray(rng.standard_normal(1 << 20), jnp.float32)
    thr = jnp.float32(2.0)
    us = _time(lambda s, t: tf_ops.topk_filter(s, t, use_pallas=False),
               scores, thr)
    emit("kernel.topk_filter.ref_1M", us, "pure-jnp oracle")

    state = topk_mod.init(1024)
    ids = jnp.arange(1 << 16, dtype=jnp.int32)
    sc = jnp.asarray(rng.standard_normal(1 << 16), jnp.float32)
    upd = jax.jit(topk_mod.update)
    us = _time(upd, state, sc, ids)
    emit("reservoir.update_64k_batch_k1024", us, "lax sort-merge path")
    us = _time(lambda st, s, i: tf_ops.filter_then_merge(st, s, i), state, sc,
               ids, reps=5)
    emit("reservoir.filter_then_merge_64k_k1024", us,
         "kernel filter + tiny exact merge")
