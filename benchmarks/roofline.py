"""Roofline table from the dry-run artifacts (artifacts/dryrun/*.json).

Per (arch × shape × mesh): the three per-chip terms, the bottleneck, the
MODEL_FLOPS/HLO_FLOPS "useful compute" ratio, and memory fit. Also renders
EXPERIMENTS.md-ready markdown to artifacts/roofline_table.md."""
from __future__ import annotations

import glob
import json
import os
import time

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def load_records(mesh: str = "single"):
    recs = []
    for f in sorted(glob.glob(os.path.join(ART, "dryrun", f"*__{mesh}.json"))):
        r = json.load(open(f))
        recs.append(r)
    return recs


def render_markdown(recs) -> str:
    lines = [
        "| arch | shape | mesh | params | t_compute | t_memory | t_collective"
        " | bottleneck | useful=6ND/HLO | arg GB/chip | tmp GB/chip |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — "
                         f"| — | — | SKIP: {r['reason'][:40]} | — | — | — |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                         f"| — | — | — | — | ERROR | — | — | — |")
            continue
        roof = r["roofline"]
        mem = r["memory"]
        uf = r.get("useful_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['n_params']/1e9:.1f}B "
            f"| {roof['t_compute_s']:.2e}s | {roof['t_memory_s']:.2e}s "
            f"| {roof['t_collective_s']:.2e}s | {roof['bottleneck']} "
            f"| {uf if uf is None else format(uf, '.3f')} "
            f"| {mem.get('argument_bytes', 0)/2**30:.2f} "
            f"| {mem.get('temp_bytes', 0)/2**30:.2f} |")
    return "\n".join(lines)


def run(emit):
    t0 = time.perf_counter_ns()
    out_lines = []
    for mesh in ("single", "multi"):
        recs = load_records(mesh)
        ok = [r for r in recs if r.get("status") == "ok"]
        skip = [r for r in recs if r.get("status") == "skipped"]
        err = [r for r in recs if r.get("status") not in ("ok", "skipped")]
        us = (time.perf_counter_ns() - t0) / 1000.0
        emit(f"roofline.{mesh}.cells", us,
             f"ok={len(ok)} skipped={len(skip)} errors={len(err)}")
        if err:
            for r in err:
                emit(f"roofline.{mesh}.error", us,
                     f"{r['arch']}x{r['shape']}")
        for r in ok:
            roof = r["roofline"]
            emit(f"roofline.{mesh}.{r['arch']}.{r['shape']}", us,
                 f"bneck={roof['bottleneck']} t_bound={roof['t_bound_s']:.2e}s")
        out_lines.append(f"### mesh: {mesh}\n\n" + render_markdown(recs))
    path = os.path.join(ART, "roofline_table.md")
    with open(path, "w") as f:
        f.write("\n\n".join(out_lines) + "\n")
    emit("roofline.table_written", 0.0, path)
