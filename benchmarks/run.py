# One function per paper table. Print ``name,us_per_call,derived`` CSV.
# ``--json`` additionally writes one BENCH_<module>.json trajectory file per
# module (deterministic: sorted keys, rows in emission order) under
# ``--out-dir`` so bench artifacts don't land in the repo root.
import argparse
import json
import os
import sys
import traceback


def write_trajectory(name: str, rows: list, path: str | None = None,
                     out_dir: str | None = None) -> str:
    """Write one BENCH_<name>.json trajectory file (the uniform format all
    bench entry points share)."""
    if path is None:
        d = out_dir or "."
        os.makedirs(d, exist_ok=True)
        path = os.path.join(d, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump({"bench": name, "rows": rows}, f, indent=1, sort_keys=True)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run a single module (tables|curves|fig8|writes|"
                         "kernels|roofline|streams|planner)")
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_<module>.json per module")
    ap.add_argument("--out-dir", default="bench_out",
                    help="directory for BENCH_*.json artifacts "
                         "(default: bench_out)")
    args = ap.parse_args()
    from benchmarks import (algo_writes, fig8_trace, fig_curves,
                            kernels_bench, paper_tables, planner_bench,
                            roofline, streams_bench)
    modules = {
        "tables": paper_tables,    # Tables I & II + the 3-tier S3 table
        "curves": fig_curves,      # Figures 4 & 5
        "fig8": fig8_trace,        # Figure 8 trace validation
        "writes": algo_writes,     # eqs. 2-8
        "kernels": kernels_bench,  # Pallas-op microbench
        "roofline": roofline,      # dry-run roofline table
        "streams": streams_bench,  # multi-tenant fleet engine throughput
        "planner": planner_bench,  # closed-form fleet planning throughput
    }
    failures = 0
    print("name,us_per_call,derived")
    for name, mod in modules.items():
        if args.only and name != args.only:
            continue
        rows = []

        def emit(row_name: str, us_per_call: float, derived: str = "") -> None:
            print(f"{row_name},{us_per_call:.1f},{derived}")
            rows.append({"name": row_name, "us_per_call": us_per_call,
                         "derived": derived})

        try:
            mod.run(emit)
        except Exception as e:
            failures += 1
            emit(f"{name}.FAILED", 0.0, repr(e))
            traceback.print_exc(file=sys.stderr)
        if args.json:
            write_trajectory(name, rows, out_dir=args.out_dir)
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
