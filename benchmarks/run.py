# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import argparse
import sys
import traceback


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run a single module (tables|curves|fig8|writes|"
                         "kernels|roofline)")
    args = ap.parse_args()
    from benchmarks import (algo_writes, fig8_trace, fig_curves,
                            kernels_bench, paper_tables, roofline)
    modules = {
        "tables": paper_tables,    # Tables I & II
        "curves": fig_curves,      # Figures 4 & 5
        "fig8": fig8_trace,        # Figure 8 trace validation
        "writes": algo_writes,     # eqs. 2-8
        "kernels": kernels_bench,  # Pallas-op microbench
        "roofline": roofline,      # dry-run roofline table
    }
    failures = 0
    print("name,us_per_call,derived")
    for name, mod in modules.items():
        if args.only and name != args.only:
            continue
        try:
            mod.run(emit)
        except Exception as e:
            failures += 1
            emit(f"{name}.FAILED", 0.0, repr(e))
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
