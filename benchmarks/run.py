# One function per paper table. Print ``name,us_per_call,derived`` CSV.
# ``--json`` additionally writes one BENCH_<module>.json trajectory file per
# module (deterministic: sorted keys, rows in emission order) under
# ``--out-dir`` so bench artifacts don't land in the repo root.
# ``--check`` compares the fresh rows against the committed repo-root
# snapshots with a tolerance band and fails the run on planner-throughput
# regressions, writing the full diff as a BENCH_diff.json artifact.
import argparse
import json
import os
import platform
import re
import sys
import time
import traceback

# the bench trajectory was previously unguarded: rows guarded here fail
# the run when a fresh measurement is slower than the committed snapshot
# by more than the tolerance band (same-machine comparison; CI runners
# are noisy, hence the generous band and the restriction to the
# largest-size rows — small-M rows jitter well past any sane band)
GUARD_PREFIXES = ("planner.", "online.")
GUARD_SUFFIXES = (".M64000", ".R256")
CHECK_TOLERANCE = 0.30

# fleet-mesh scaling rows (``<base>.sharded_dN`` / ``<base>.ref1``) are
# guarded against their SAME-RUN single-device reference, never the
# committed snapshot: forced CPU meshes only parallelize up to the
# machine's real core count, so the floor is calibrated to it — the
# acceptance 2x on a >=4-effective-core mesh, a soft fraction of the
# effective parallelism below that (a 1-core box can't speed up at all;
# the guard then only catches sharding that *destroys* throughput)
_SHARDED_RE = re.compile(r"^(?P<base>.+)\.sharded_d(?P<d>\d+)$")
SHARD_FLOOR_FULL = 2.0

# cost-ledger overhead ceiling: each ``engine_step_costobs_*`` row is
# paired with its SAME-RUN ``engine_step_obs_*`` twin (identical fleet,
# batch, and interleaved rounds — the delta is the device CostState
# fold alone) and must stay within 5% of it
_COSTOBS_RE = re.compile(r"^streams\.engine_step_costobs_(?P<size>.+)$")
COSTOBS_TOLERANCE = 0.05

# chunk-boundary checkpointing ceiling: each ``engine_step_ckpt_*`` row
# is paired with its SAME-RUN ``engine_step_ckptoff_*`` twin (identical
# fleet, chunks, interleaved rounds — the delta is the snapshot + async
# npy handoff alone, tail wait included) and must stay within 10% of it
_CKPT_RE = re.compile(r"^streams\.engine_step_ckpt_(?P<size>.+)$")
CKPT_TOLERANCE = 0.10

# engine-backend memory floor: each ``<base>.logmem`` row is paired with
# its SAME-RUN ``<base>.exact`` row by the ``bytes_per_stream`` extras —
# device bytes are deterministic, so the floor has no tolerance band.
# The O(log K) backend must stay >= 8x leaner than the O(K) reservoir at
# K >= 4096 (at small K the fixed O(log K) footprint eats the margin)
_BACKEND_RE = re.compile(r"^(?P<base>.+)\.(?P<backend>exact|logmem)$")
MEMORY_FLOOR_FULL_K = 4096
MEMORY_FLOOR_FULL = 8.0
MEMORY_FLOOR_SMALL = 4.0


def memory_ratio_floor(k: int) -> float:
    return (MEMORY_FLOOR_FULL if k >= MEMORY_FLOOR_FULL_K
            else MEMORY_FLOOR_SMALL)


def shard_speedup_floor(devices: int) -> float:
    eff = min(devices, os.cpu_count() or 1)
    return SHARD_FLOOR_FULL if eff >= 4 else 0.45 * eff


def _guarded(name: str) -> bool:
    return (name.startswith(GUARD_PREFIXES)
            and name.endswith(GUARD_SUFFIXES))


def host_meta() -> dict:
    """The measurement context stamped into every trajectory file: which
    machine and numeric regime produced the numbers (cross-machine
    comparisons lean on ``_numpy_oracle`` calibration, but the metadata
    makes the provenance inspectable)."""
    meta = {"platform": platform.platform(),
            "cpu_count": os.cpu_count(),
            "python": platform.python_version()}
    try:
        import jax
        meta["jax_version"] = jax.__version__
        meta["jax_backend"] = jax.default_backend()
        meta["jax_x64"] = bool(jax.config.jax_enable_x64)
    except Exception:  # pragma: no cover - jax is a hard dep in practice
        meta["jax_version"] = None
    return meta


def write_trajectory(name: str, rows: list, path: str | None = None,
                     out_dir: str | None = None) -> str:
    """Write one BENCH_<name>.json trajectory file (the uniform format all
    bench entry points share): sorted keys, rows in emission order, plus
    the host-metadata block."""
    if path is None:
        d = out_dir or "."
        os.makedirs(d, exist_ok=True)
        path = os.path.join(d, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump({"bench": name, "host": host_meta(), "rows": rows},
                  f, indent=1, sort_keys=True)
    return path


def check_regressions(fresh: dict, baseline_dir: str = ".",
                      tol: float = CHECK_TOLERANCE,
                      out_dir: str | None = None) -> list:
    """Compare fresh rows ({module: rows}) against the committed
    ``BENCH_<module>.json`` snapshots.

    Rows are matched by name; a *guarded* row (``GUARD_PREFIXES``)
    regresses when ``fresh_us > committed_us * (1 + tol)``. Unmatched or
    unguarded rows are reported informationally only. Writes the full
    comparison to ``BENCH_diff.json`` under ``out_dir`` (the CI
    artifact) and returns the list of regression dicts."""
    diff, regressions = [], []
    for module, rows in fresh.items():
        base_path = os.path.join(baseline_dir, f"BENCH_{module}.json")
        committed = {}
        if os.path.exists(base_path):
            with open(base_path) as f:
                committed = {r["name"]: r for r in json.load(f)["rows"]}
        # cross-machine calibration: the committed snapshot was produced
        # on some machine; the `_numpy_oracle` reference rows measure the
        # same unchanged host code on both, so their ratio estimates the
        # machine-speed delta and rescales the comparison
        scales = [row["us_per_call"] / committed[row["name"]]["us_per_call"]
                  for row in rows
                  if "_numpy_oracle" in row["name"]
                  and row["name"] in committed
                  and committed[row["name"]]["us_per_call"]]
        scale = sorted(scales)[len(scales) // 2] if scales else 1.0
        for row in rows:
            name = row["name"]
            entry = {"name": name, "us_new": row["us_per_call"],
                     "guarded": _guarded(name), "machine_scale": scale}
            old = committed.get(name)
            if old is None:
                entry["status"] = "new"
            else:
                entry["us_committed"] = old["us_per_call"]
                ratio = (row["us_per_call"]
                         / (old["us_per_call"] * scale)
                         if old["us_per_call"] else float("inf"))
                entry["ratio"] = ratio
                slow = ratio > 1.0 + tol
                entry["status"] = ("regression" if slow and entry["guarded"]
                                   else "slower" if slow else "ok")
                if entry["status"] == "regression":
                    regressions.append(entry)
            diff.append(entry)
        # a guarded committed row that no fresh row matches means the
        # guard was silently defeated (renamed emit label, changed size
        # constant, dropped row) — fail loudly instead of passing green
        fresh_names = {row["name"] for row in rows}
        for name, old in committed.items():
            if _guarded(name) and name not in fresh_names:
                entry = {"name": name, "us_committed": old["us_per_call"],
                         "guarded": True, "status": "missing"}
                regressions.append(entry)
                diff.append(entry)
        # fleet-mesh rows: same-run pairing against the .ref1 reference
        by_name = {row["name"]: row for row in rows}
        for row in rows:
            match = _SHARDED_RE.match(row["name"])
            if match is None:
                continue
            devices = int(match.group("d"))
            floor = shard_speedup_floor(devices)
            entry = {"name": row["name"], "us_new": row["us_per_call"],
                     "guarded": True, "floor": floor,
                     "effective_cores": min(devices, os.cpu_count() or 1)}
            ref = by_name.get(match.group("base") + ".ref1")
            if ref is None or not row["us_per_call"]:
                entry["status"] = "missing_ref"
                regressions.append(entry)
            else:
                speedup = ref["us_per_call"] / row["us_per_call"]
                entry["us_ref1"] = ref["us_per_call"]
                entry["speedup"] = speedup
                entry["status"] = ("sharded_slow" if speedup < floor
                                   else "ok")
                if entry["status"] == "sharded_slow":
                    regressions.append(entry)
            diff.append(entry)
        # cost-ledger rows: same-run pairing against the obs twin — the
        # device CostState fold must stay within COSTOBS_TOLERANCE of
        # the metrics-only step (min-of-interleaved-rounds on both
        # sides, so the comparison carries no cross-machine assumptions)
        for row in rows:
            match = _COSTOBS_RE.match(row["name"])
            if match is None:
                continue
            entry = {"name": row["name"], "us_new": row["us_per_call"],
                     "guarded": True, "tol": COSTOBS_TOLERANCE}
            ref = by_name.get(
                f"streams.engine_step_obs_{match.group('size')}")
            if ref is None or not ref["us_per_call"]:
                entry["status"] = "missing_obs_ref"
                regressions.append(entry)
            else:
                overhead = row["us_per_call"] / ref["us_per_call"] - 1.0
                entry["us_obs"] = ref["us_per_call"]
                entry["overhead"] = overhead
                entry["status"] = ("costobs_slow"
                                   if overhead > COSTOBS_TOLERANCE
                                   else "ok")
                if entry["status"] == "costobs_slow":
                    regressions.append(entry)
            diff.append(entry)
        # checkpointing rows: same-run pairing against the no-checkpoint
        # twin — the chunk-boundary snapshot + async write handoff must
        # stay within CKPT_TOLERANCE of the bare ingest loop
        for row in rows:
            match = _CKPT_RE.match(row["name"])
            if match is None:
                continue
            entry = {"name": row["name"], "us_new": row["us_per_call"],
                     "guarded": True, "tol": CKPT_TOLERANCE}
            ref = by_name.get(
                f"streams.engine_step_ckptoff_{match.group('size')}")
            if ref is None or not ref["us_per_call"]:
                entry["status"] = "missing_ckptoff_ref"
                regressions.append(entry)
            else:
                overhead = row["us_per_call"] / ref["us_per_call"] - 1.0
                entry["us_ckptoff"] = ref["us_per_call"]
                entry["overhead"] = overhead
                entry["status"] = ("ckpt_slow"
                                   if overhead > CKPT_TOLERANCE
                                   else "ok")
                if entry["status"] == "ckpt_slow":
                    regressions.append(entry)
            diff.append(entry)
        # engine-backend rows: same-run memory pairing — a logmem row
        # whose exact twin is missing (or whose bytes advantage drops
        # under the floor) fails the run
        for row in rows:
            match = _BACKEND_RE.match(row["name"])
            if match is None or match.group("backend") != "logmem" \
                    or "bytes_per_stream" not in row:
                continue
            k = int(row.get("k", 0))
            floor = memory_ratio_floor(k)
            entry = {"name": row["name"], "guarded": True, "k": k,
                     "floor": floor,
                     "bytes_logmem": row["bytes_per_stream"]}
            ref = by_name.get(match.group("base") + ".exact")
            if (ref is None or "bytes_per_stream" not in ref
                    or not row["bytes_per_stream"]):
                entry["status"] = "missing_pair"
                regressions.append(entry)
            else:
                ratio = ref["bytes_per_stream"] / row["bytes_per_stream"]
                entry["bytes_exact"] = ref["bytes_per_stream"]
                entry["bytes_ratio"] = ratio
                entry["status"] = ("logmem_memory" if ratio < floor
                                   else "ok")
                if entry["status"] == "logmem_memory":
                    regressions.append(entry)
            diff.append(entry)
    path = write_trajectory("diff", diff, out_dir=out_dir)
    print(f"wrote {path} ({len(regressions)} guarded regression(s), "
          f"tolerance {tol:.0%})")
    for entry in regressions:
        if entry["status"] == "missing":
            print(f"  MISSING guarded row {entry['name']} "
                  f"(committed {entry['us_committed']:.1f}us)")
        elif entry["status"] == "missing_ref":
            print(f"  MISSING same-run .ref1 reference for "
                  f"{entry['name']}")
        elif entry["status"] == "sharded_slow":
            print(f"  SHARDED-SLOW {entry['name']}: "
                  f"{entry['speedup']:.2f}x vs same-run ref, floor "
                  f"{entry['floor']:.2f}x "
                  f"({entry['effective_cores']} effective core(s))")
        elif entry["status"] == "missing_obs_ref":
            print(f"  MISSING same-run engine_step_obs twin for "
                  f"{entry['name']}")
        elif entry["status"] == "costobs_slow":
            print(f"  COSTOBS-SLOW {entry['name']}: "
                  f"{entry['overhead']:+.1%} over the same-run obs twin "
                  f"({entry['us_new']:.1f}us vs {entry['us_obs']:.1f}us), "
                  f"ceiling {entry['tol']:.0%}")
        elif entry["status"] == "missing_ckptoff_ref":
            print(f"  MISSING same-run engine_step_ckptoff twin for "
                  f"{entry['name']}")
        elif entry["status"] == "ckpt_slow":
            print(f"  CKPT-SLOW {entry['name']}: "
                  f"{entry['overhead']:+.1%} over the same-run "
                  f"no-checkpoint twin ({entry['us_new']:.1f}us vs "
                  f"{entry['us_ckptoff']:.1f}us), ceiling "
                  f"{entry['tol']:.0%}")
        elif entry["status"] == "missing_pair":
            print(f"  MISSING same-run .exact memory pair for "
                  f"{entry['name']}")
        elif entry["status"] == "logmem_memory":
            print(f"  LOGMEM-MEMORY {entry['name']}: only "
                  f"{entry['bytes_ratio']:.1f}x leaner than exact "
                  f"({entry['bytes_logmem']:.0f} vs "
                  f"{entry['bytes_exact']:.0f} B/stream), floor "
                  f"{entry['floor']:.1f}x at K={entry['k']}")
        else:
            print(f"  REGRESSION {entry['name']}: "
                  f"{entry['us_committed']:.1f}us -> "
                  f"{entry['us_new']:.1f}us ({entry['ratio']:.2f}x)")
    return regressions


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run a single module (tables|curves|fig8|writes|"
                         "kernels|roofline|streams|planner)")
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_<module>.json per module")
    ap.add_argument("--out-dir", default="bench_out",
                    help="directory for BENCH_*.json artifacts "
                         "(default: bench_out)")
    ap.add_argument("--check", action="store_true",
                    help="compare fresh rows against the committed "
                         "BENCH_*.json snapshots; exit 1 on guarded "
                         "(planner/online) regressions beyond the band")
    ap.add_argument("--check-tol", type=float, default=CHECK_TOLERANCE,
                    help="relative slowdown tolerated by --check "
                         "(default: 0.30)")
    ap.add_argument("--baseline-dir", default=".",
                    help="directory holding the committed snapshots "
                         "(default: repo root)")
    args = ap.parse_args()
    from benchmarks import (algo_writes, fig8_trace, fig_curves,
                            kernels_bench, paper_tables, planner_bench,
                            roofline, streams_bench)
    modules = {
        "tables": paper_tables,    # Tables I & II + the 3-tier S3 table
        "curves": fig_curves,      # Figures 4 & 5
        "fig8": fig8_trace,        # Figure 8 trace validation
        "writes": algo_writes,     # eqs. 2-8
        "kernels": kernels_bench,  # Pallas-op microbench
        "roofline": roofline,      # dry-run roofline table
        "streams": streams_bench,  # multi-tenant fleet engine throughput
        "planner": planner_bench,  # closed-form fleet planning throughput
    }
    failures = 0
    fresh = {}
    print("name,us_per_call,derived")
    for name, mod in modules.items():
        if args.only and name != args.only:
            continue
        rows = []

        def emit(row_name: str, us_per_call: float, derived: str = "",
                 **extra) -> None:
            print(f"{row_name},{us_per_call:.1f},{derived}")
            rows.append({"name": row_name, "us_per_call": us_per_call,
                         "derived": derived, **extra, "ts": time.time()})

        try:
            mod.run(emit)
        except Exception as e:
            failures += 1
            emit(f"{name}.FAILED", 0.0, repr(e))
            traceback.print_exc(file=sys.stderr)
        fresh[name] = rows
        if args.json:
            write_trajectory(name, rows, out_dir=args.out_dir)
    regressions = []
    if args.check:
        regressions = check_regressions(fresh, args.baseline_dir,
                                        args.check_tol, args.out_dir)
    if failures or regressions:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
