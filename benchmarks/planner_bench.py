"""Proactive-planner throughput: time the vectorized closed-form fleet
planner at M in {1k, 16k, 64k} streams, two-tier (legacy ``plan_fleet``
over a prebuilt ``FleetCosts``), three-tier (the multi-threshold
``shp.plan_ntier_arrays``), and the constrained variants (per-tier
capacity masks; capacity + read-path SLO through the exact joint solve).
The paper's tractability claim is that the whole fleet plans in closed
form before any document arrives — this bench tracks that planning stays
off the ingest critical path as M grows, and what the constraint
machinery costs on top.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import shp
from repro.streams import planner

SIZES = (1_000, 16_000, 64_000)


def _rand(rng, m, lo=1e-8, hi=1e-3):
    return 10.0 ** rng.uniform(np.log10(lo), np.log10(hi), m)


def _two_tier_costs(rng, m) -> planner.FleetCosts:
    n = rng.integers(10_000, 1_000_000, m).astype(np.float64)
    k = np.maximum(1, (n * rng.uniform(0.001, 0.1, m))).astype(np.float64)
    return planner.FleetCosts(
        cw_a=_rand(rng, m), cw_b=_rand(rng, m), cr_a=_rand(rng, m),
        cr_b=_rand(rng, m), cs_a=_rand(rng, m), cs_b=_rand(rng, m),
        n=n, k=k, reads_per_window=np.ones(m))


def _ntier_arrays(rng, m, t):
    n = rng.integers(10_000, 1_000_000, m).astype(np.float64)
    k = np.maximum(1, (n * rng.uniform(0.001, 0.1, m))).astype(np.float64)
    return (_rand(rng, (m, t)), _rand(rng, (m, t)), _rand(rng, (m, t)),
            n, k, np.ones(m))


def _constraint_arrays(rng, m, t, k, with_slo):
    """Per-tier capacities (hot tier capped at a fraction of K) and, when
    ``with_slo``, per-tier latencies rising with depth plus a binding
    per-stream SLO."""
    cap = np.full((m, t), np.inf)
    cap[:, 0] = k * rng.uniform(0.1, 2.0, m)
    lat = np.zeros((m, t))
    slo = np.full(m, np.inf)
    if with_slo:
        lat = 10.0 ** rng.uniform(-3, 2, (m, t))
        lat.sort(axis=1)
        slo = 10.0 ** rng.uniform(np.log10(np.maximum(lat[:, 0], 1e-6)),
                                  np.log10(lat[:, -1] + 1e-6))
    return cap, lat, slo


def _time(fn, repeats=3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(emit):
    rng = np.random.default_rng(0)
    for m in SIZES:
        fc = _two_tier_costs(rng, m)
        sec = _time(lambda: planner.plan_fleet(fc))
        emit(f"planner.two_tier.M{m}", sec * 1e6,
             f"{m / sec:.0f} streams/s")
        args = _ntier_arrays(rng, m, 3)
        sec = _time(lambda: shp.plan_ntier_arrays(*args))
        emit(f"planner.three_tier.M{m}", sec * 1e6,
             f"{m / sec:.0f} streams/s")
        cap, lat, slo = _constraint_arrays(rng, m, 3, args[4], False)
        sec = _time(lambda: shp.plan_ntier_arrays(*args, cap=cap, lat=lat,
                                                  slo=slo), repeats=2)
        emit(f"planner.three_tier_capacity.M{m}", sec * 1e6,
             f"{m / sec:.0f} streams/s")
        cap, lat, slo = _constraint_arrays(rng, m, 3, args[4], True)
        sec = _time(lambda: shp.plan_ntier_arrays(*args, cap=cap, lat=lat,
                                                  slo=slo), repeats=2)
        emit(f"planner.three_tier_cap_slo.M{m}", sec * 1e6,
             f"{m / sec:.0f} streams/s")


def main():
    import argparse
    try:
        from benchmarks.run import write_trajectory
    except ImportError:
        from run import write_trajectory
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write a BENCH_planner.json trajectory file")
    args = ap.parse_args()
    rows = []

    def emit(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}")
        rows.append({"name": name, "us_per_call": us, "derived": derived})

    run(emit)
    if args.json:
        print(f"wrote {write_trajectory('planner', rows, args.json)}")


if __name__ == "__main__":
    main()
