"""Proactive-planner throughput: time the vectorized closed-form fleet
planner at M in {1k, 16k, 64k} streams, two-tier (legacy ``plan_fleet``
over a prebuilt ``FleetCosts``), three-tier (the multi-threshold
``shp.plan_ntier_arrays``), and the constrained variants (per-tier
capacity masks; capacity + read-path SLO through the exact joint solve).
The paper's tractability claim is that the whole fleet plans in closed
form before any document arrives — this bench tracks that planning stays
off the ingest critical path as M grows, and what the constraint
machinery costs on top.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import shp
from repro.obs import timers
from repro.streams import planner

SIZES = (1_000, 16_000, 64_000)


def _rand(rng, m, lo=1e-8, hi=1e-3):
    return 10.0 ** rng.uniform(np.log10(lo), np.log10(hi), m)


def _two_tier_costs(rng, m) -> planner.FleetCosts:
    n = rng.integers(10_000, 1_000_000, m).astype(np.float64)
    k = np.maximum(1, (n * rng.uniform(0.001, 0.1, m))).astype(np.float64)
    return planner.FleetCosts(
        cw_a=_rand(rng, m), cw_b=_rand(rng, m), cr_a=_rand(rng, m),
        cr_b=_rand(rng, m), cs_a=_rand(rng, m), cs_b=_rand(rng, m),
        n=n, k=k, reads_per_window=np.ones(m))


def _ntier_arrays(rng, m, t):
    n = rng.integers(10_000, 1_000_000, m).astype(np.float64)
    k = np.maximum(1, (n * rng.uniform(0.001, 0.1, m))).astype(np.float64)
    return (_rand(rng, (m, t)), _rand(rng, (m, t)), _rand(rng, (m, t)),
            n, k, np.ones(m))


def _constraint_arrays(rng, m, t, k, with_slo):
    """Per-tier capacities (hot tier capped at a fraction of K) and, when
    ``with_slo``, per-tier latencies rising with depth plus a binding
    per-stream SLO."""
    cap = np.full((m, t), np.inf)
    cap[:, 0] = k * rng.uniform(0.1, 2.0, m)
    lat = np.zeros((m, t))
    slo = np.full(m, np.inf)
    if with_slo:
        lat = 10.0 ** rng.uniform(-3, 2, (m, t))
        lat.sort(axis=1)
        slo = 10.0 ** rng.uniform(np.log10(np.maximum(lat[:, 0], 1e-6)),
                                  np.log10(lat[:, -1] + 1e-6))
    return cap, lat, slo


_time = timers.time_best  # the shared best-of-N host-call discipline


def run(emit):
    rng = np.random.default_rng(0)
    for m in SIZES:
        fc = _two_tier_costs(rng, m)
        sec = _time(lambda: planner.plan_fleet(fc))
        emit(f"planner.two_tier.M{m}", sec * 1e6,
             f"{m / sec:.0f} streams/s")
        # the shipped dispatch: the jitted device solver for fleets
        # (core.shp_jax + kernels.plan_solve; f32 unconstrained / f64
        # constrained — see the README float64 policy)
        args = _ntier_arrays(rng, m, 3)
        sec = _time(lambda: shp.plan_ntier_arrays(*args))
        emit(f"planner.three_tier.M{m}", sec * 1e6,
             f"{m / sec:.0f} streams/s (jit device solver)")
        cap, lat, slo = _constraint_arrays(rng, m, 3, args[4], False)
        sec = _time(lambda: shp.plan_ntier_arrays(*args, cap=cap, lat=lat,
                                                  slo=slo), repeats=2)
        emit(f"planner.three_tier_capacity.M{m}", sec * 1e6,
             f"{m / sec:.0f} streams/s (jit device solver)")
        cap, lat, slo = _constraint_arrays(rng, m, 3, args[4], True)
        sec = _time(lambda: shp.plan_ntier_arrays(*args, cap=cap, lat=lat,
                                                  slo=slo), repeats=2)
        emit(f"planner.three_tier_cap_slo.M{m}", sec * 1e6,
             f"{m / sec:.0f} streams/s (jit device solver)")
        if m == SIZES[-1]:
            # the NumPy oracle at the largest M: the before/after
            # reference the device rows are measured against
            sec = _time(lambda: shp.plan_ntier_arrays(
                *args, backend="numpy"), repeats=2)
            emit(f"planner.three_tier_numpy_oracle.M{m}", sec * 1e6,
                 f"{m / sec:.0f} streams/s (host reference)")
            sec = _time(lambda: shp.plan_ntier_arrays(
                *args, cap=cap, lat=lat, slo=slo, backend="numpy"),
                repeats=2)
            emit(f"planner.three_tier_cap_slo_numpy_oracle.M{m}",
                 sec * 1e6, f"{m / sec:.0f} streams/s (host reference)")
    _sharded_plan_rows(emit, rng)
    _run_online_resolve(emit, rng)


def _sharded_plan_rows(emit, rng):
    """Fleet-mesh scaling of the candidate-grid solve at the largest M:
    the same solve single-device (L2-chunk thread fan-out) vs dispatched
    per shard, emitted as a same-run ``.ref1``/``.sharded_dN`` pair for
    the machine-honest ``run.py --check`` guard. Requires a multi-device
    mesh (CI forces 8 CPU devices); silently absent otherwise."""
    import jax
    from repro.parallel import fleet
    mesh = fleet.fleet_mesh(min(jax.local_device_count(), 8))
    if mesh is None:
        return
    shards = fleet.n_shards(mesh)
    m = SIZES[-1]
    args = _ntier_arrays(rng, m, 3)

    def base():
        return shp.plan_ntier_arrays(*args)

    def sharded():
        with fleet.use_fleet_mesh(mesh):
            return shp.plan_ntier_arrays(*args)

    base(), sharded()  # compile both paths outside the timed rounds
    key = f"sharded_d{shards}"
    best = {"ref1": float("inf"), key: float("inf")}
    for _ in range(4):  # interleaved best-of: same machine weather
        best["ref1"] = min(best["ref1"], _time(base, repeats=1))
        best[key] = min(best[key], _time(sharded, repeats=1))
    sec = best["ref1"]
    emit(f"planner.three_tier.M{m}.ref1", sec * 1e6,
         f"{m / sec:.0f} streams/s single-device reference")
    sec = best[key]
    emit(f"planner.three_tier.M{m}.{key}", sec * 1e6,
         f"{m / sec:.0f} streams/s on {shards} shards "
         f"({best['ref1'] / sec:.2f}x vs same-run 1-device ref)")


def _online_models(rng, r, t):
    """Heterogeneous N-tier models with interior crossovers for the
    online re-solve latency rows."""
    from repro.core import costs as costs_mod, topology
    models = []
    for _ in range(r):
        wl = costs_mod.WorkloadSpec(n_docs=int(rng.integers(10_000, 50_000)),
                                    k=int(rng.integers(16, 128)),
                                    doc_gb=1e-4, window_months=0.5)
        tiers = []
        put = 1e-6
        get = 3e-4
        rent = 0.05
        for _ in range(t):
            tiers.append(topology.TierSpec(costs_mod.TierCosts(
                "t", put_per_doc=put * float(rng.uniform(0.8, 1.2)),
                get_per_doc=get * float(rng.uniform(0.8, 1.2)),
                storage_per_gb_month=rent)))
            put *= 40.0
            get /= 40.0
            rent /= 3.0
        models.append(topology.TierTopology(tiers=tuple(tiers))
                      .cost_model(wl))
    return models


def _run_online_resolve(emit, rng):
    """Online re-plan latency: the constrained suffix re-solve for a batch
    of drift-flagged streams (repro.online.replan) — the piece that must
    stay off the ingest critical path when detections fire."""
    from repro.online.replan import Replanner
    for t, r in ((2, 256), (3, 256)):
        models = _online_models(rng, r, t)
        rp = Replanner(models)
        n = np.array([m.workload.n_docs for m in models], np.float64)
        n0 = 0.3 * n
        rho = np.full(r, 6.0)
        bounds = [tuple([0.29 * n[i]] * (t - 1)) for i in range(r)]
        mig = np.zeros(r, bool)
        sec = _time(lambda: rp.replan(np.arange(r), n0, rho, bounds, mig),
                    repeats=6)
        emit(f"online.resolve_{t}tier.R{r}", sec * 1e6,
             f"{r / sec:.0f} streams/s suffix re-solve (jit device)")


def main():
    import argparse
    try:
        from benchmarks.run import write_trajectory
    except ImportError:
        from run import write_trajectory
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write a BENCH_planner.json trajectory file")
    args = ap.parse_args()
    rows = []

    def emit(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}")
        rows.append({"name": name, "us_per_call": us, "derived": derived,
                     "ts": time.time()})

    run(emit)
    if args.json:
        print(f"wrote {write_trajectory('planner', rows, args.json)}")


if __name__ == "__main__":
    main()
