"""Figure 8 — cumulative document writes: trace-driven simulation vs the
analytic model (eqs. 11/12), for (a) an exactly-random-rank trace and
(b) the synthetic GRN label-entropy trace (stand-in for the paper's
unpublished SVM trace), plus the adversarial sorted trace where the model's
random-order assumption is deliberately violated."""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import placement, shp, simulator

OUT = os.path.join(os.path.dirname(__file__), "..", "artifacts", "curves")


def run(emit):
    n, k = 100_000, 100
    rng = np.random.default_rng(2019)
    analytic = shp.expected_cum_writes(np.arange(n), k)
    rows = {"analytic": analytic}
    for name, trace in [
        ("random_rank", simulator.random_rank_trace(n, rng)),
        ("grn_entropy", simulator.grn_entropy_trace(n, rng)),
        ("sorted_adversarial", simulator.sorted_adversarial_trace(n)),
    ]:
        t0 = time.perf_counter_ns()
        res = simulator.simulate(trace, k, placement.all_tier_a(n))
        us = (time.perf_counter_ns() - t0) / 1000.0
        rows[name] = res.cum_writes
        rel = abs(res.cum_writes[-1] - analytic[-1]) / analytic[-1]
        emit(f"fig8.{name}.total_writes", us,
             f"{res.cum_writes[-1]} (analytic {analytic[-1]:.0f}, "
             f"rel_err {rel:.3f})")
    os.makedirs(OUT, exist_ok=True)
    idx = np.arange(n)
    data = np.column_stack([idx] + [np.asarray(rows[kk], dtype=np.float64)
                                    for kk in rows])
    np.savetxt(os.path.join(OUT, "fig8_cumulative_writes.csv"), data[::100],
               delimiter=",", header="i," + ",".join(rows), comments="")
    # the paper's claim: randomly-ordered traces obey the law; sorted doesn't
    assert abs(rows["random_rank"][-1] - analytic[-1]) / analytic[-1] < 0.05
    assert abs(rows["grn_entropy"][-1] - analytic[-1]) / analytic[-1] < 0.10
    assert rows["sorted_adversarial"][-1] == n
