"""Tables I & II — the paper's two cloud case studies, recomputed from the
listed prices. Prints each strategy's expected cost next to the paper's
printed value (two of which are not derivable from the listed prices; see
DESIGN.md §9)."""
from __future__ import annotations

import time

from repro.core import costs, shp


def _strategies(cm):
    rows = []
    r_nm = shp.r_optimal_no_migration(cm)
    r_mg = shp.r_optimal_migration(cm)
    if shp.r_is_valid(cm, r_nm):
        rows.append(("two_tier_no_migration@r*", shp.cost_no_migration(cm, r_nm),
                     r_nm / cm.workload.n_docs))
    if shp.r_is_valid(cm, r_mg):
        rows.append(("two_tier_migration@r*", shp.cost_with_migration(cm, r_mg),
                     r_mg / cm.workload.n_docs))
    rows.append(("all_tier_a", shp.cost_single_tier(cm, "a"), 1.0))
    rows.append(("all_tier_b", shp.cost_single_tier(cm, "b"), 0.0))
    return rows


def table1(emit):
    cm = costs.case_study_1()
    t0 = time.perf_counter_ns()
    r = shp.r_optimal_no_migration(cm)
    plan = shp.plan_placement(cm)
    us = (time.perf_counter_ns() - t0) / 1000.0
    paper = {"r_over_n": 0.41233169, "two_tier_no_migration@r*": 35.19,
             "two_tier_migration@r": 49.29, "all_tier_a": 37.20,
             "all_tier_b": 99.12}
    emit("table1.r_opt_over_N", us, f"{r / cm.workload.n_docs:.6f}"
         f" (paper {paper['r_over_n']})")
    for name, sc, rn in _strategies(cm):
        emit(f"table1.{name}", us, f"${sc.total:.2f}")
    # the paper's migration row is evaluated at the no-migration r*
    mig_at_r = shp.cost_with_migration(cm, 0.41233169 * cm.workload.n_docs)
    emit("table1.two_tier_migration@r_nm", us,
         f"${mig_at_r.total:.2f} (paper {paper['two_tier_migration@r']})")
    emit("table1.chosen_strategy", us, plan.strategy)
    assert abs(r / cm.workload.n_docs - 0.41233169) < 5e-4
    assert abs(shp.cost_no_migration(cm, r).total - 35.19) < 0.02


def table2(emit):
    cm = costs.case_study_2()
    t0 = time.perf_counter_ns()
    r = shp.r_optimal_migration(cm)
    plan = shp.plan_placement(cm)
    us = (time.perf_counter_ns() - t0) / 1000.0
    emit("table2.r_opt_over_N", us, f"{r / cm.workload.n_docs:.6f} (paper 0.078)")
    for name, sc, rn in _strategies(cm):
        emit(f"table2.{name}", us, f"${sc.total:.2f}")
    emit("table2.chosen_strategy", us, plan.strategy)
    # paper: migration 142.82 (eq. 20), all-A 350.00
    assert abs(shp.cost_single_tier(cm, "a").total - 350.00) < 1e-6
    assert abs(shp.cost_with_migration(cm, r).total - 142.82) < 2.1


def run(emit):
    table1(emit)
    table2(emit)
