"""Tables I & II — the paper's two cloud case studies, recomputed from the
listed prices — plus the 3-tier S3 Standard/IA/Glacier table the N-tier
generalization adds. Prints each strategy's expected cost next to the
paper's printed value (two of which are not derivable from the listed
prices; see DESIGN.md §9). Also asserts the two-tier totals reproduce
bit-identically (at printed precision) through the ``NTierCostModel``
path, so the generalized stack can never drift from the paper."""
from __future__ import annotations

import time

import numpy as np

from repro.core import costs, shp, topology


def _strategies(cm):
    rows = []
    r_nm = shp.r_optimal_no_migration(cm)
    r_mg = shp.r_optimal_migration(cm)
    if shp.r_is_valid(cm, r_nm):
        rows.append(("two_tier_no_migration@r*", shp.cost_no_migration(cm, r_nm),
                     r_nm / cm.workload.n_docs))
    if shp.r_is_valid(cm, r_mg):
        rows.append(("two_tier_migration@r*", shp.cost_with_migration(cm, r_mg),
                     r_mg / cm.workload.n_docs))
    rows.append(("all_tier_a", shp.cost_single_tier(cm, "a"), 1.0))
    rows.append(("all_tier_b", shp.cost_single_tier(cm, "b"), 0.0))
    return rows


def table1(emit):
    cm = costs.case_study_1()
    t0 = time.perf_counter_ns()
    r = shp.r_optimal_no_migration(cm)
    plan = shp.plan_placement(cm)
    us = (time.perf_counter_ns() - t0) / 1000.0
    paper = {"r_over_n": 0.41233169, "two_tier_no_migration@r*": 35.19,
             "two_tier_migration@r": 49.29, "all_tier_a": 37.20,
             "all_tier_b": 99.12}
    emit("table1.r_opt_over_N", us, f"{r / cm.workload.n_docs:.6f}"
         f" (paper {paper['r_over_n']})")
    for name, sc, rn in _strategies(cm):
        emit(f"table1.{name}", us, f"${sc.total:.2f}")
    # the paper's migration row is evaluated at the no-migration r*
    mig_at_r = shp.cost_with_migration(cm, 0.41233169 * cm.workload.n_docs)
    emit("table1.two_tier_migration@r_nm", us,
         f"${mig_at_r.total:.2f} (paper {paper['two_tier_migration@r']})")
    emit("table1.chosen_strategy", us, plan.strategy)
    assert abs(r / cm.workload.n_docs - 0.41233169) < 5e-4
    assert abs(shp.cost_no_migration(cm, r).total - 35.19) < 0.02


def table2(emit):
    cm = costs.case_study_2()
    t0 = time.perf_counter_ns()
    r = shp.r_optimal_migration(cm)
    plan = shp.plan_placement(cm)
    us = (time.perf_counter_ns() - t0) / 1000.0
    emit("table2.r_opt_over_N", us, f"{r / cm.workload.n_docs:.6f} (paper 0.078)")
    for name, sc, rn in _strategies(cm):
        emit(f"table2.{name}", us, f"${sc.total:.2f}")
    emit("table2.chosen_strategy", us, plan.strategy)
    # paper: migration 142.82 (eq. 20), all-A 350.00
    assert abs(shp.cost_single_tier(cm, "a").total - 350.00) < 1e-6
    assert abs(shp.cost_with_migration(cm, r).total - 142.82) < 2.1


def table_ntier_compat(emit):
    """Both case studies through the N-tier path: same chosen strategy, and
    every strategy total identical to the two-tier path at printed (cent)
    precision."""
    for i, cm in enumerate((costs.case_study_1(), costs.case_study_2()), 1):
        t0 = time.perf_counter_ns()
        nt = cm.as_ntier()
        legacy = shp.plan_placement(cm)
        npl = shp.plan_placement(nt)
        assert npl.strategy == legacy.strategy, (npl.strategy, legacy.strategy)
        assert f"{npl.total:.2f}" == f"{legacy.best.total:.2f}"
        for r in (shp.r_optimal_no_migration(cm), shp.r_optimal_migration(cm)):
            if shp.r_is_valid(cm, r):
                two = shp.cost_no_migration(cm, r).total
                n_ = shp.cost_ntier_no_migration(nt, (r,)).total
                assert f"{two:.2f}" == f"{n_:.2f}", (two, n_)
                two = shp.cost_with_migration(cm, r).total
                n_ = shp.cost_ntier_migration(nt, (r,)).total
                assert f"{two:.2f}" == f"{n_:.2f}", (two, n_)
        us = (time.perf_counter_ns() - t0) / 1000.0
        emit(f"ntier_compat.case_study_{i}", us,
             f"{npl.strategy} ${npl.total:.2f} == two-tier path")


def table_3tier(emit):
    """The new table: case study 2 extended one tier down — EFS → S3
    Standard → Glacier-IR under a 1MB / 3-month top-K window. A genuinely
    3-boundary migration cascade, verified against brute-force grid search.
    Also the Standard → Standard-IA → Glacier-IR lifecycle hierarchy, where
    the N-tier validity gate *collapses* the IA tier: its per-request touch
    cost always outweighs its rental advantage, so the optimal cascade
    skips straight to Glacier."""
    topo = topology.aws_efs_s3_glacier()
    wl = costs.WorkloadSpec(n_docs=int(1e8), k=int(1e5), doc_gb=1e-3,
                            window_months=3.0)
    model = topo.cost_model(wl)
    t0 = time.perf_counter_ns()
    plan = shp.plan_placement_ntier(model)
    us = (time.perf_counter_ns() - t0) / 1000.0
    n = wl.n_docs
    for t, name in enumerate(model.tier_names):
        sc = shp.cost_ntier_no_migration(model, shp.single_tier_bounds(model, t))
        emit(f"table3.all_{name}", us, f"${sc.total:.2f}")
    bs = ",".join(f"{b / n:.4f}" for b in plan.boundaries)
    emit("table3.chosen_strategy", us, f"{plan.strategy} @ [{bs}]")
    emit("table3.chosen_total", us, f"${plan.total:.2f}")
    bt, _, bm = shp.brute_force_plan_ntier(model, grid=48)
    emit("table3.brute_force", us, f"${bt:.2f} migrate={bm}")
    assert plan.strategy == "ntier_migration"
    assert np.all(np.diff([0.0, *plan.boundaries, n]) > 0)  # 3 tiers used
    assert plan.total <= bt * (1 + 1e-9)
    assert abs(plan.total - bt) <= 0.02 * bt
    # the lifecycle hierarchy: IA collapses (validity gate in action)
    ia_model = topology.aws_s3_tiering().cost_model(wl)
    ia_plan = shp.plan_placement_ntier(ia_model)
    widths = np.diff([0.0, *ia_plan.boundaries, n])
    emit("table3.std_ia_glacier", us,
         f"{ia_plan.strategy} ${ia_plan.total:.2f} "
         f"(IA width {widths[1] / n:.4f} — collapsed)")
    assert widths[1] == 0.0


def run(emit):
    table1(emit)
    table2(emit)
    table_ntier_compat(emit)
    table_3tier(emit)
